"""Batched multi-RHS solves: A X = B for (n, m) right-hand sides.

Krasnopolsky ("Revisiting Performance of BiCGStab Methods for Solving
Systems with Multiple Right-Hand Sides") observes that blocked BiCGStab
variants win not by sharing the Krylov space but by *amortizing memory
traffic and reduction latency* across right-hand sides: every vector phase
streams (n, m) blocks instead of m separate (n,) vectors, and the m
synchronization phases collapse into one.  Applied to the paper's
pipelined single-synchronization methods this is maximal leverage: the
batched p-BiCGSafe iteration below performs ONE ``dot_reduce`` of a
``(9, m)`` partial block per iteration — the same single message as the
m=1 solver, now carrying the inner products of all m systems — and the
fused-dots phase still reads only ``{s, y, r, t_prev, rs}``, preserving
the no-dependency-edge overlap with the in-flight block matvec.

Each column keeps its own coefficients (alpha_j, beta_j, zeta_j, eta_j) —
this is the "individual" blocked mode: convergence per column is
identical to m independent solves in exact arithmetic, and columns that
converge (or break down) early are frozen by masking while the rest
continue.  ``benchmarks/bench_multirhs.py`` measures batched vs. looped.

The whole hot loop routes through the compute substrate
(:mod:`repro.core.substrate`): on ``substrate="pallas"`` the fused
(9, m) dots, the (n, m) update phase (with the convergence mask applied
in-kernel) and the block-ELL SpMV are the hand-tiled kernels, and on the
distributed driver (:func:`repro.core.distributed
.distributed_stencil_solve_batched`) the same iteration runs per shard
with the (9, m) partial block reduced by ONE psum.

Open-loop API (the substrate of :mod:`repro.service`)
-----------------------------------------------------
The iteration is exposed in three jit-friendly pieces so a *continuous
batching* serving layer can keep one resident (n, max_batch) block alive
across heterogeneous requests:

* :func:`init_state`      — build the per-column Krylov state pytree,
* :func:`step_chunk`      — advance ALL columns by up to k iterations with
                            ONE compiled program (early-exits when every
                            column is frozen; still one (9, m) reduction
                            per iteration),
* :func:`splice_columns`  — retire/refill: overwrite a masked subset of
                            columns with fresh right-hand sides and reset
                            per-column Krylov state, mid-flight.  Columns
                            are independent in "individual" blocked mode,
                            so splicing is exact — the surviving columns'
                            trajectories are untouched.

State is per-column throughout: ``tol`` and ``maxiter`` are ``(m,)``
vectors (scalars broadcast via :func:`repro.core.types.per_column`) and
the i=0 coefficient branch keys off each column's OWN iteration count, so
a column spliced into a long-running block starts from its proper first
iteration.  :func:`solve_batched` is the closed-loop wrapper: init + one
chunk of ``config.maxiter`` iterations (behavior-preserving — the
refactor is regression-pinned bitwise in tests/test_substrate_parity.py).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..precond.base import PrecondLike, wrap_block_preconditioned
from ._common import (bicgsafe_breakdown_code, bicgsafe_coefficients,
                      pipelined_recurrence_tail)
from .substrate import SubstrateLike, get_substrate
from .types import (DotReduce, SolveResult, SolveStatus, SolverConfig,
                    identity_reduce, per_column, trace_init, trace_record)

#: Per-column health/monitor fields carried by a GUARDED state pytree
#: (``SolverConfig.guard``); their presence marks a state as guarded.
GUARD_FIELDS = ("status", "drift", "drift_flag", "stall", "best_relres",
                "stagnant", "replacements", "restarts")


def _guard_init(m: int, rdtype, conv0: jax.Array) -> dict:
    """Fresh guard-field values for ``m`` columns (``conv0``: columns that
    are converged at t=0, i.e. zero right-hand sides)."""
    return dict(
        status=jnp.where(conv0, SolveStatus.CONVERGED.value,
                         SolveStatus.RUNNING.value).astype(jnp.int32),
        drift=jnp.zeros((m,), rdtype),
        drift_flag=jnp.zeros((m,), bool),
        stall=jnp.zeros((m,), jnp.int32),
        best_relres=jnp.full((m,), jnp.inf, rdtype),
        stagnant=jnp.zeros((m,), bool),
        replacements=jnp.zeros((m,), jnp.int32),
        restarts=jnp.zeros((m,), jnp.int32))


def _masked(mask_cols, new, old):
    """Per-column select: mask is (m,); operands are (m,) or (n, m).

    ``new`` may arrive with the trailing RHS axis squeezed away — e.g. a
    user ``dot_reduce`` that collapses the degenerate ``(9, 1)`` partial
    block to ``(9,)`` for m=1 turns every coefficient into a scalar.  Such
    lower-rank ``new`` values are broadcast back up to ``old``'s shape
    instead of raising: the state block's shape is authoritative.
    """
    if new.ndim < old.ndim and old.shape[-1] == 1:  # squeezed m=1 only
        new = jnp.broadcast_to(
            new.reshape(new.shape + (1,) * (old.ndim - new.ndim)),
            old.shape)
    elif new.ndim != old.ndim:
        # m>1 stays a loud failure: a dot_reduce that collapses the RHS
        # axis of a real block would otherwise broadcast one column's
        # coefficients to all m
        raise ValueError(
            f"rank mismatch: new {new.shape} vs old {old.shape}")
    m = mask_cols if new.ndim == 1 else mask_cols[None, :]
    return jnp.where(m, new, old)


def batched_matvec(matvec: Callable) -> Callable:
    """Lift a single-vector matvec (n,)->(n,) to (n, m) column blocks."""
    return jax.vmap(matvec, in_axes=1, out_axes=1)


def active_columns(state: dict) -> jax.Array:
    """(m,) bool: columns still iterating (not converged / broken down /
    past their per-column iteration budget)."""
    return ((~state["converged"]) & (~state["breakdown"])
            & (state["iterations"] < state["col_maxiter"]))


def init_state(bmv: Callable,
               B: jax.Array,
               X0: Optional[jax.Array] = None,
               *,
               config: SolverConfig = SolverConfig(),
               r0_star: Optional[jax.Array] = None,
               dot_reduce: DotReduce = identity_reduce,
               substrate: SubstrateLike = "jnp",
               tol=None,
               maxiter=None) -> dict:
    """Build the batched p-BiCGSafe state pytree for ``A X = B``.

    Args:
      bmv: the ``(n, m) -> (n, m)`` block matvec — already lifted (and
        already left-preconditioned, with ``B`` the preconditioned block,
        when preconditioning is in play; :func:`solve_batched` and the
        service registry do this composition).
      B: (n, m) right-hand sides.
      X0: optional (n, m) initial guesses.
      config/r0_star/dot_reduce/substrate: as for :func:`solve_batched`.
      tol: per-column tolerance — scalar or (m,); defaults to
        ``config.tol`` for every column.
      maxiter: per-column iteration budget — scalar or (m,); defaults to
        ``config.maxiter``.  A column stops advancing once its OWN count
        reaches its budget (:func:`active_columns`), which is what lets
        heterogeneous requests share one block.

    Costs one ``dot_reduce`` (the per-column ||r_0||) plus one block
    matvec (S_0 = A R_0; two with a nonzero ``X0``).  The returned dict is
    a pytree of arrays only — it jits, donates, and shards cleanly.
    """
    sub = get_substrate(substrate)
    n, m = B.shape
    X = jnp.zeros_like(B) if X0 is None else X0.astype(B.dtype)
    R0 = B - bmv(X) if X0 is not None else B
    if r0_star is None:
        RS = R0
    else:
        RS = r0_star.astype(B.dtype)
        if RS.ndim == 1:
            RS = jnp.broadcast_to(RS[:, None], B.shape)
    S0 = bmv(R0)                                  # block MV (init): A R_0

    norm_r0 = jnp.sqrt(dot_reduce(sub.dots([(R0, R0)]))[0])   # (m,)
    # Zero right-hand side (or exact initial guess): ||r_0|| == 0 means X
    # already solves that column — mark it converged at t=0 with relres 0
    # instead of letting the body divide by norm_r0 and poison the column
    # with NaN.  Nonzero columns take the same values as before bitwise.
    # (broadcast: a squeezing dot_reduce may return norm_r0 as a scalar
    # for m=1, but the per-column carries must stay (m,))
    conv0 = jnp.broadcast_to(norm_r0 == 0, (m,))
    Z0 = jnp.zeros_like(B)
    ones_m = jnp.ones((m,), B.dtype)
    if config.record_history:
        hist = jnp.full((config.maxiter + 1, m), jnp.nan, norm_r0.dtype)
    else:
        hist = jnp.zeros((0, m), norm_r0.dtype)

    tol_col = per_column(config.tol if tol is None else tol,
                         m, norm_r0.dtype, name="tol")
    maxiter_col = per_column(config.maxiter if maxiter is None else maxiter,
                             m, jnp.int32, name="maxiter")

    st = dict(
        x=X, r=R0, s=S0, p=Z0, u=Z0, t=Z0, y=Z0, z=Z0, w=Z0, l=Z0, g=Z0,
        rs=RS,
        alpha=jnp.zeros((m,), B.dtype), zeta=ones_m, f=ones_m,
        i=jnp.zeros((), jnp.int32),
        iterations=jnp.zeros((m,), jnp.int32),
        relres=jnp.where(conv0, 0.0, 1.0).astype(norm_r0.dtype),
        converged=conv0, breakdown=jnp.zeros((m,), bool),
        norm_r0=norm_r0, tol=tol_col, col_maxiter=maxiter_col,
        hist=hist)
    if config.guard:
        st.update(_guard_init(m, norm_r0.dtype, conv0))
    if config.trace_cap:
        st["trace"] = trace_init(config, norm_r0.dtype, m)
    return st


def splice_columns(bmv: Callable,
                   state: dict,
                   refill: jax.Array,
                   B_new: jax.Array,
                   *,
                   r0_star: Optional[jax.Array] = None,
                   dot_reduce: DotReduce = identity_reduce,
                   substrate: SubstrateLike = "jnp",
                   tol=None,
                   maxiter=None) -> dict:
    """Refill a masked subset of columns with fresh right-hand sides.

    Args:
      bmv: the block matvec the state is being stepped with.
      state: live state pytree from :func:`init_state` / :func:`step_chunk`.
      refill: (m,) bool — True columns are overwritten, False columns are
        carried through bit-untouched (columns are independent in
        "individual" blocked mode, so this is exact, not approximate).
      B_new: (n, m) block holding the fresh right-hand sides in the True
        columns (other columns are ignored).  Fresh columns start from
        x0 = 0.
      r0_star: optional (n,) / (n, m) shadow residual for the fresh
        columns (defaults to their r_0, as in :func:`init_state`).
      tol/maxiter: per-column settings for the fresh columns — scalar or
        (m,) (entries of False columns are ignored).

    Costs one block matvec (A R_0 of the fresh columns, computed on the
    full block so the splice is ONE compiled program for any refill
    count — the frozen columns ride along as zero columns) and one
    ``dot_reduce``.  The global step counter ``i`` (history indexing) is
    preserved; every per-column field of the fresh columns is reset
    exactly as :func:`init_state` builds it.
    """
    m = state["r"].shape[1]
    sub = get_substrate(substrate)
    refill = refill.astype(bool)
    col = refill[None, :]
    B_live = jnp.where(col, B_new.astype(state["r"].dtype), 0.0)
    S0 = bmv(B_live)             # zero columns stay zero: bmv is linear
    norm_new = jnp.sqrt(dot_reduce(sub.dots([(B_live, B_live)]))[0])

    if r0_star is None:
        RS_new = B_live
    else:
        RS_new = r0_star.astype(B_live.dtype)
        if RS_new.ndim == 1:
            RS_new = jnp.broadcast_to(RS_new[:, None], B_live.shape)

    dt = state["r"].dtype
    tol_col = per_column(state["tol"] if tol is None else tol,
                         m, state["tol"].dtype, name="tol")
    maxiter_col = per_column(
        state["col_maxiter"] if maxiter is None else maxiter,
        m, jnp.int32, name="maxiter")

    def vec(new, old):                      # (n, m) fields
        return jnp.where(col, new, old)

    def sca(new, old):                      # (m,) fields
        return jnp.where(refill, new, old)

    zero_m = jnp.zeros((m,), dt)
    out = dict(state)
    out["x"] = vec(jnp.zeros_like(B_live), state["x"])
    out["r"] = vec(B_live, state["r"])
    out["s"] = vec(S0, state["s"])
    out["rs"] = vec(RS_new, state["rs"])
    for k in ("p", "u", "t", "y", "z", "w", "l", "g"):
        out[k] = vec(jnp.zeros_like(B_live), state[k])
    out["alpha"] = sca(zero_m, state["alpha"])
    out["zeta"] = sca(jnp.ones((m,), dt), state["zeta"])
    out["f"] = sca(jnp.ones((m,), dt), state["f"])
    out["iterations"] = sca(jnp.zeros((m,), jnp.int32), state["iterations"])
    # Zero right-hand sides spliced in are converged at t=0 (see
    # init_state) — same guard against the norm_r0 division.
    conv_new = jnp.broadcast_to(norm_new == 0, (m,))
    out["relres"] = sca(jnp.where(conv_new, 0.0, 1.0
                                  ).astype(state["relres"].dtype),
                        state["relres"])
    out["converged"] = sca(conv_new, state["converged"])
    out["breakdown"] = sca(jnp.zeros((m,), bool), state["breakdown"])
    out["norm_r0"] = sca(norm_new, state["norm_r0"])
    out["tol"] = sca(tol_col, state["tol"])
    out["col_maxiter"] = sca(maxiter_col, state["col_maxiter"])
    if state["hist"].shape[0]:
        out["hist"] = jnp.where(col, jnp.nan, state["hist"])
    if "trace" in state:
        # refilled columns start a fresh trajectory: NaN their trace
        # rows (same pattern as hist); the ring keeps recording from
        # the CURRENT global slot, which the harvest layer handles
        out["trace"] = jnp.where(refill[None, None, :], jnp.nan,
                                 state["trace"])
    if "status" in state:                        # guarded state: fresh
        fresh = _guard_init(m, state["norm_r0"].dtype, conv_new)
        for k in GUARD_FIELDS:
            out[k] = sca(fresh[k], state[k])
    return out


def _make_body(sub, bmv: Callable, config: SolverConfig,
               dot_reduce: DotReduce) -> Callable:
    """One batched p-BiCGSafe iteration: state dict -> state dict.

    Shared verbatim by :func:`solve_batched` and :func:`step_chunk` — the
    single (9, m) reduction, the in-kernel convergence mask, and the
    overlap structure live here and ONLY here.

    With ``config.guard`` the fused phase is the (11, m) health variant
    (same single reduction, same operand independence from the in-flight
    matvec) and the state additionally carries per-column typed status
    codes, a NaN/Inf detector, the Cools drift bound for on-trigger
    residual replacement, and a stagnation counter — everything
    :class:`repro.resilience.GuardedSolver` reads at chunk boundaries.
    Unguarded, the emitted program is bit-for-bit the historical one.
    """
    guard = config.guard

    def body(st):
        r, s, y, t_prev = st["r"], st["s"], st["y"], st["t"]
        RS = st["rs"]
        eps = config.breakdown_threshold(r.dtype)
        active = active_columns(st)                               # (m,)

        # Block MV and the single fused (9, m) reduction — mutually
        # independent, exactly as in the m=1 pipelined iteration.  The
        # guarded (11, m) phase additionally reads the PREVIOUS iterate
        # x (a loop-carried value, no edge to As) for its health rows.
        # (named scopes land in HLO op metadata so the runtime profiler
        # can attribute device time to phases; no ops, bitwise-unchanged.)
        with jax.named_scope("repro.matvec"):
            As = bmv(s)
        with jax.named_scope("repro.reduce"):
            if guard:
                dots = dot_reduce(
                    sub.bicgsafe_dots_health(s, y, r, t_prev, RS, st["x"]))
            else:
                dots = dot_reduce(sub.bicgsafe_dots(s, y, r, t_prev, RS))

        # Each column's i=0 branch keys off its OWN iteration count, so a
        # freshly spliced column in a long-running block initializes its
        # coefficients correctly (for a monolithic solve this is
        # indistinguishable from the global counter).
        beta, alpha, zeta, eta, f, rr, bad = bicgsafe_coefficients(
            dots, st["iterations"], st["alpha"], st["zeta"], st["f"],
            eps)                                                  # (m,)
        relres = jnp.sqrt(jnp.abs(rr)) / st["norm_r0"]
        done = relres <= st["tol"]

        if guard:
            # In-reduction health: rows 9/10 of the fused phase.  A
            # non-finite probe (NaN/Inf anywhere in s/y/t/rs/x) or rr/xx
            # freezes the column exactly like a coefficient breakdown —
            # the poisoned vectors never advance, so NaN cannot spread to
            # the rest of the resident block's history.
            xx, probe = dots[9], dots[10]
            nonfinite = ~(jnp.isfinite(probe) & jnp.isfinite(rr)
                          & jnp.isfinite(xx))
            code = bicgsafe_breakdown_code(
                dots, st["iterations"], st["alpha"], st["zeta"], st["f"],
                eps)
            bad = bad | nonfinite

        # Per-RHS freeze mask: only active-and-unfinished columns advance;
        # converged / broken-down columns stay at their final state.
        advance = active & ~done & ~bad               # (m,)

        # Blocked vector-update phase through the substrate (the (m,)
        # coefficients broadcast over the (n, m) column blocks).  The
        # convergence mask rides into the phase — on the pallas substrate
        # frozen columns write their input tiles back inside the kernel,
        # so no second (n, m) masking pass is needed for these outputs.
        with jax.named_scope("repro.axpy"):
            upd = sub.axpy_phase(
                dict(r=r, p=st["p"], u=st["u"], t=t_prev, y=y, z=st["z"],
                     s=s, l=st["l"], g=st["g"], w=st["w"], x=st["x"],
                     As=As),
                (alpha, beta, zeta, eta), mask=advance)
        p, u, q, w, t = (upd[k] for k in ("p", "u", "q", "w", "t"))
        z, y_next, x_next, r_next = (
            upd[k] for k in ("z", "y", "x", "r"))

        with jax.named_scope("repro.matvec"):
            Aw = bmv(w)                               # block MV #2
        with jax.named_scope("repro.axpy"):
            l, g_next, s_next = pipelined_recurrence_tail(
                q, s, As, st["g"], Aw, alpha, zeta, eta)

        # The recurrence tail (l, g, s) and the scalar carries have no
        # in-kernel mask — freeze them here.
        upd = lambda new, old: _masked(advance, new, old)  # noqa: E731
        relres_out = _masked(active, relres, st["relres"])
        if config.record_history:
            hist_i = st["hist"].at[st["i"]].set(
                jnp.where(active, relres_out.astype(st["hist"].dtype),
                          st["hist"][st["i"]]))
        else:
            hist_i = st["hist"]

        iters_next = jnp.where(advance, st["iterations"] + 1,
                               st["iterations"])
        out = dict(
            x=x_next, r=r_next, s=upd(s_next, s),
            p=p, u=u, t=t, y=y_next, z=z, w=w,
            l=upd(l, st["l"]), g=upd(g_next, st["g"]),
            rs=RS,
            alpha=upd(alpha, st["alpha"]), zeta=upd(zeta, st["zeta"]),
            f=upd(f, st["f"]),
            i=st["i"] + 1,
            iterations=iters_next,
            relres=relres_out,
            converged=st["converged"] | (active & done),
            breakdown=st["breakdown"] | (active & bad & ~done),
            norm_r0=st["norm_r0"], tol=st["tol"],
            col_maxiter=st["col_maxiter"],
            hist=hist_i)

        if guard:
            # Typed per-column status: first terminal event wins; columns
            # that burn their budget are stamped MAXITER as they cross it.
            sts = st["status"]
            sts = jnp.where(active & done,
                            SolveStatus.CONVERGED.value, sts)
            sts = jnp.where(active & ~done & nonfinite,
                            SolveStatus.NONFINITE.value, sts)
            sts = jnp.where(active & ~done & ~nonfinite & bad,
                            jnp.maximum(code, SolveStatus.BREAKDOWN.value),
                            sts)
            sts = jnp.where(advance & (iters_next >= st["col_maxiter"])
                            & (sts == SolveStatus.RUNNING.value),
                            SolveStatus.MAXITER.value, sts)

            # Cools / van-der-Vorst–Ye drift bound: the gap between the
            # recurred and true residual grows like
            # eps * sum_i (||A|| ||x_i|| + ||r_i||); once the bound
            # approaches the ABSOLUTE tolerance tol * ||r_0|| (times
            # drift_scale), the recurred residual can no longer be
            # trusted for the convergence decision and the policy should
            # force a replacement.  ||A|| is estimated in-flight as
            # ||A r||/||r|| = sqrt(a/rr) — row 0 over row 8, free.
            normr = jnp.sqrt(jnp.abs(rr))
            eps_mach = jnp.finfo(r.dtype).eps
            tiny = jnp.finfo(r.dtype).tiny
            normA = jnp.sqrt(jnp.abs(dots[0])
                             / jnp.maximum(jnp.abs(rr), tiny))
            inc = eps_mach * (normA * jnp.sqrt(jnp.abs(xx)) + normr)
            drift = jnp.where(advance, st["drift"] + inc, st["drift"])
            drift_flag = st["drift_flag"] | (
                advance
                & (drift > config.drift_threshold(r.dtype)
                   * st["tol"] * st["norm_r0"]))

            # Stagnation monitor: consecutive iterations without a new
            # best relative residual; sticky flag once the window is hit.
            improved = relres < st["best_relres"]
            best = jnp.where(advance & improved, relres,
                             st["best_relres"])
            stall = jnp.where(advance,
                              jnp.where(improved, 0, st["stall"] + 1),
                              st["stall"])
            if config.stagnation_window > 0:
                stagnant = st["stagnant"] | (
                    stall >= config.stagnation_window)
            else:
                stagnant = st["stagnant"]

            out.update(status=sts, drift=drift, drift_flag=drift_flag,
                       stall=stall, best_relres=best, stagnant=stagnant,
                       replacements=st["replacements"],
                       restarts=st["restarts"])

        if config.trace_cap:
            # Write-only iteration trace: every channel is a value this
            # iteration already computed (the denominators re-express
            # safe_div inputs — XLA CSEs them; the first-iteration
            # omega pivot is ``a``, matching bicgsafe_coefficients).
            # No reduction, no edge to As/Aw — contract-verified.
            a_d, b_d, c_d = dots[0], dots[1], dots[2]
            g_d, h_d = dots[6], dots[7]
            first = st["iterations"] == 0
            if guard:
                drift_ch, status_ch = out["drift"], out["status"]
            else:
                drift_ch = jnp.zeros_like(relres_out)
                status_ch = jnp.where(
                    out["converged"], SolveStatus.CONVERGED.value,
                    jnp.where(out["breakdown"],
                              SolveStatus.BREAKDOWN.value,
                              SolveStatus.RUNNING.value))
            # iteration channel = COMPLETED updates when relres was
            # measured (pre-advance): the terminal detection row keeps
            # the final count and the CONVERGED/BREAKDOWN status.
            out["trace"] = trace_record(st["trace"], st["i"], (
                st["iterations"], relres_out,
                st["zeta"] * st["f"],
                g_d + beta * h_d,
                jnp.where(first, a_d, a_d * b_d - c_d * c_d),
                drift_ch, status_ch))
        return out

    return body


def step_chunk(bmv: Callable,
               state: dict,
               k: int,
               *,
               config: SolverConfig = SolverConfig(),
               dot_reduce: DotReduce = identity_reduce,
               substrate: SubstrateLike = "jnp") -> dict:
    """Advance every live column by up to ``k`` iterations.

    ONE ``lax.while_loop`` — hence one compiled program per (shape, k)
    regardless of which request mix occupies the columns — that exits
    early once every column is frozen (converged, broken down, or past
    its per-column budget).  Each executed iteration performs exactly one
    ``dot_reduce`` of the (9, m) partial block, with no dependency edge
    to the in-flight block matvec (asserted on the engine's step program
    in tests/test_service.py).

    ``k`` must be static under jit (it bounds the loop).  The global
    counter ``state["i"]`` keeps counting across chunks; per-column
    ``iterations`` count from each column's own start (splice resets
    them).
    """
    body = _make_body(get_substrate(substrate), bmv, config, dot_reduce)

    def cond(carry):
        j, st = carry
        return jnp.any(active_columns(st)) & (j < k)

    def step(carry):
        j, st = carry
        return j + 1, body(st)

    _, st = jax.lax.while_loop(cond, step, (jnp.zeros((), jnp.int32), state))
    return st


def result_from_state(state: dict) -> SolveResult:
    """Package a state pytree as the public :class:`SolveResult`.

    ``status``: guarded states carry their typed per-column code through
    the iteration (finalized here: still-RUNNING columns past budget ->
    MAXITER); unguarded states get the coarse classification, with
    still-active columns (open-loop mid-flight packaging) left RUNNING.
    """
    from .types import classify_status
    if "status" in state:
        sts = state["status"]
        running = sts == SolveStatus.RUNNING.value
        sts = jnp.where(running & state["converged"],
                        SolveStatus.CONVERGED.value, sts)
        sts = jnp.where(running & state["breakdown"] & ~state["converged"],
                        SolveStatus.BREAKDOWN.value, sts)
        sts = jnp.where((sts == SolveStatus.RUNNING.value)
                        & (state["iterations"] >= state["col_maxiter"]),
                        SolveStatus.MAXITER.value, sts)
    else:
        sts = jnp.where(
            active_columns(state), SolveStatus.RUNNING.value,
            classify_status(state["converged"], state["breakdown"],
                            state["relres"]))
    trace = None
    if "trace" in state:
        trace = {"buffer": state["trace"], "steps": state["i"]}
    return SolveResult(state["x"], state["iterations"], state["relres"],
                       state["converged"], state["breakdown"],
                       state["hist"], sts.astype(jnp.int32), trace)


def solve_batched(matvec: Callable,
                  B: jax.Array,
                  X0: Optional[jax.Array] = None,
                  *,
                  config: SolverConfig = SolverConfig(),
                  r0_star: Optional[jax.Array] = None,
                  dot_reduce: DotReduce = identity_reduce,
                  substrate: SubstrateLike = "jnp",
                  blocked: bool = False,
                  precond: PrecondLike = None,
                  tol=None) -> SolveResult:
    """Solve A X = B with p-BiCGSafe for all m columns of B at once.

    Args:
      matvec: single-vector matvec (n,) -> (n,); lifted to column blocks
        by the substrate (vmap, or the block-ELL kernel for banded ELL
        operators on the pallas substrate).  May also be an operator
        accepted by the substrate.
      B: (n, m) right-hand sides.
      X0: optional (n, m) initial guesses.
      config/r0_star/dot_reduce/substrate: as for the single-RHS solvers;
        ``r0_star`` is a single (n,) shadow vector shared by all columns
        or an (n, m) block of per-column shadows.
      blocked: the given ``matvec`` already maps (n, m) column blocks to
        (n, m) — used by the distributed driver, whose halo-exchange
        matvec streams whole blocks (one ppermute cascade for all m).
      precond: optional left preconditioner (name or
        :class:`repro.precond.Preconditioner`): the solve runs on
        M^{-1} A with M^{-1} B, every column through the SAME M^{-1}
        (its apply is column-batched, in-kernel for block-Jacobi on the
        pallas substrate), still ONE (9, m) reduction per iteration.
        With ``blocked=True`` pass an instance — name specs need the
        operator object to build from.
      tol: per-column tolerance — scalar or ``(m,)`` vector (heterogeneous
        right-hand sides each converge against their own tolerance);
        defaults to ``config.tol`` broadcast to every column.

    Returns a :class:`SolveResult` with column-batched fields: ``x`` is
    (n, m); ``iterations``, ``relres``, ``converged``, ``breakdown`` are
    (m,); ``residual_history`` is (maxiter+1, m) when recorded.

    One ``dot_reduce`` call per iteration regardless of m (the (9, m)
    partial block is one message), plus one for ||r_0||.  The whole
    per-iteration vector phase — fused dots, update phase, block SpMV —
    runs through the substrate, so ``substrate="pallas"`` executes it on
    the hand-tiled (n, m) kernels with the per-column convergence mask
    applied in-kernel.

    This is the closed-loop wrapper over the open-loop API: one
    :func:`init_state` plus one :func:`step_chunk` of ``config.maxiter``
    iterations (bitwise-equal to the historical monolithic loop —
    regression-pinned in tests/test_substrate_parity.py).
    """
    if B.ndim != 2:
        raise ValueError(f"B must be (n, m); got shape {B.shape}")
    sub = get_substrate(substrate)
    bmv = matvec if blocked else sub.as_block_matvec(matvec)
    bmv, B = wrap_block_preconditioned(sub, bmv, B, precond, matvec)
    state = init_state(bmv, B, X0, config=config, r0_star=r0_star,
                       dot_reduce=dot_reduce, substrate=sub, tol=tol)
    state = step_chunk(bmv, state, config.maxiter, config=config,
                       dot_reduce=dot_reduce, substrate=sub)
    return result_from_state(state)
