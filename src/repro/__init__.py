"""repro — communication-hiding pipelined BiCGSafe, grown production-shaped.

Front door (:mod:`repro.api`): bind an operator once, solve many times —

    import repro

    solver = repro.make_solver("p-bicgsafe", op, precond="block_jacobi",
                               substrate="pallas")
    res = solver.solve(b)                  # compiled program cached
    res = solver.solve_many([b1, b2, b3])  # ONE (9, m) reduction/iter
    dist = solver.on_mesh(mesh)            # sharded, same session

    res = repro.solve(op, b)               # one-shot convenience

Sessions are content-addressed: equal-content operators share one
session (built preconditioner + compiled programs), whether they arrive
via :func:`make_solver`, :func:`solve`, or the continuous-batching
solve service (:mod:`repro.service`), whose registry consumes the same
cache.

The whole regression matrix — operator class x method x substrate x
precond x guard x batch — is declarative data (:mod:`repro.scenarios`):
register a :class:`Scenario` once and it becomes a cached session
(``make_solver(scenario="poisson-jacobi")``), a contract-audit row, and
a ``python -m repro.scenarios sweep`` cell.

Layers underneath: :mod:`repro.core` (the paper's solvers, operators,
batched/distributed drivers), :mod:`repro.kernels` (Pallas hot-loop
kernels), :mod:`repro.precond` (preconditioners inside the overlap
window), :mod:`repro.service` (continuous batching),
:mod:`repro.observe` (zero-sync iteration traces, span timelines,
metrics — ``solver.solve(b, trace=True)``).  The historical
free-function entry points keep working as deprecated shims.
"""
from repro.api import (DistributedSolver, LinearSolver, make_solver,
                       operator_fingerprint, solve)
from repro.core import (SOLVERS, CSROperator, DenseOperator, ELLOperator,
                        Preconditioner, SolveResult, SolverConfig,
                        Stencil7Operator, SUBSTRATES, get_substrate)
from repro.observe import ConvergenceTrace
from repro.resilience import GuardedSolver, RecoveryPolicy, SolveStatus
from repro.scenarios import (OperatorSpec, Scenario, register_operator_class,
                             register_scenario)

__all__ = [
    # the front door
    "make_solver", "solve", "LinearSolver", "DistributedSolver",
    "operator_fingerprint",
    # the vocabulary types the front door speaks
    "SolverConfig", "SolveResult", "SOLVERS",
    "DenseOperator", "CSROperator", "ELLOperator", "Stencil7Operator",
    "Preconditioner",
    "SUBSTRATES", "get_substrate",
    # the scenario registry (repro.scenarios; make_solver(scenario=...))
    "Scenario", "OperatorSpec", "register_scenario",
    "register_operator_class",
    # guarded solves (repro.resilience; make_solver(recovery=...))
    "SolveStatus", "RecoveryPolicy", "GuardedSolver",
    # observability (repro.observe; solve(trace=True))
    "ConvergenceTrace",
]
