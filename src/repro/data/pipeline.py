"""Data pipeline: deterministic, shardable, restartable.

Two sources:
* ``synthetic_token_stream`` — seeded Zipf-ish token batches (markov-mixed
  so the LM has actual structure to learn); fully deterministic in
  (seed, step), so restart-from-checkpoint replays identically and each
  data shard draws a disjoint stream (fault tolerance requirement).
* ``byte_tokenize`` + file source — byte-level tokenization of local text,
  packed into fixed-length rows.

Batches are dicts matching ``repro.models`` inputs.  ``make_dataset``
returns a stateless ``step -> batch`` function: the *step index is the
iterator state*, which is what makes checkpoint/restart and elastic
re-sharding trivial (no opaque iterator state to persist).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 256
    seed: int = 0
    source: str = "synthetic"         # synthetic | file
    path: Optional[str] = None
    shard_index: int = 0              # this host's data shard
    shard_count: int = 1


def byte_tokenize(text: str, vocab_size: int) -> np.ndarray:
    toks = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)
    return toks % vocab_size


def synthetic_token_stream(cfg: DataConfig, step: int) -> np.ndarray:
    """Deterministic (seed, shard, step) -> (B, S) int32 batch.

    Tokens follow a 2-state mixture: within a row, token t is with p=0.6 a
    function of token t-1 (affine mod V) and with p=0.4 Zipf-sampled — so
    cross-entropy has learnable structure (tests assert the loss drops).
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, cfg.shard_index, step]))
    B, S, V = cfg.batch_size, cfg.seq_len, cfg.vocab_size
    zipf = rng.zipf(1.5, size=(B, S)).astype(np.int64) % V
    out = np.empty((B, S), np.int64)
    out[:, 0] = zipf[:, 0]
    follow = rng.random((B, S)) < 0.6
    for t in range(1, S):
        out[:, t] = np.where(follow[:, t],
                             (out[:, t - 1] * 31 + 7) % V, zipf[:, t])
    return out.astype(np.int32)


def _file_batches(cfg: DataConfig) -> np.ndarray:
    text = Path(cfg.path).read_text(errors="replace")
    toks = byte_tokenize(text, cfg.vocab_size)
    n = (len(toks) - 1) // cfg.seq_len
    rows = toks[:n * cfg.seq_len].reshape(n, cfg.seq_len)
    return rows


def make_dataset(cfg: DataConfig, model_cfg=None) -> Callable[[int], Dict]:
    """Returns ``batch_fn(step) -> {"tokens": (B, S) int32, ...}``."""
    rows = _file_batches(cfg) if cfg.source == "file" else None

    def batch_fn(step: int) -> Dict[str, np.ndarray]:
        if cfg.source == "file":
            n = rows.shape[0]
            idx = (np.arange(cfg.batch_size)
                   + step * cfg.batch_size * cfg.shard_count
                   + cfg.shard_index * cfg.batch_size) % n
            tokens = rows[idx]
        else:
            tokens = synthetic_token_stream(cfg, step)
        batch = {"tokens": tokens}
        if model_cfg is not None and model_cfg.family == "audio":
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed + 1, cfg.shard_index, step]))
            batch["frames"] = rng.standard_normal(
                (cfg.batch_size, cfg.seq_len, model_cfg.d_model)
            ).astype(np.float32)
        if model_cfg is not None and model_cfg.family == "vlm":
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed + 2, cfg.shard_index, step]))
            n_patch = min(64, cfg.seq_len - 2)
            batch["patch_embeds"] = rng.standard_normal(
                (cfg.batch_size, n_patch, model_cfg.d_model)
            ).astype(np.float32)
            t = np.broadcast_to(np.arange(cfg.seq_len)[None, :, None],
                                (cfg.batch_size, cfg.seq_len, 3))
            batch["positions"] = np.ascontiguousarray(t, dtype=np.int32)
        return batch

    return batch_fn


def prefetch(batch_fn: Callable[[int], Dict], start_step: int = 0,
             lookahead: int = 2) -> Iterator[Dict]:
    """Simple thread prefetcher over the stateless batch function."""
    import queue
    import threading

    q: "queue.Queue" = queue.Queue(maxsize=lookahead)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put(batch_fn(step))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
