from .pipeline import (DataConfig, byte_tokenize, make_dataset,
                       synthetic_token_stream)

__all__ = ["DataConfig", "byte_tokenize", "make_dataset",
           "synthetic_token_stream"]
