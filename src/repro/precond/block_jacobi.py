"""Block-Jacobi preconditioner: pre-inverted dense diagonal blocks.

M = blockdiag(A_11, ..., A_bb) over contiguous row blocks of size ``bs``;
the setup pre-inverts every block (host-side, setup time), so the apply is
a batched dense ``(bs, bs) @ (bs,)`` multiply per block — no triangular
solves, no communication, embarrassingly parallel.  On the pallas
substrate the apply runs through the batched block-apply kernel
(:mod:`repro.kernels.precond_apply`), single-RHS and ``(n, m)`` multi-RHS.

``inv_blocks`` may be ``(1, bs, bs)``: one block shared by every row block
(the :class:`~repro.core.linear_operator.Stencil7Operator` case, whose
z-line blocks are all the same tridiagonal matrix) — the shared-block
apply is a single dense matmul which XLA already maps to the MXU, so it
skips the Pallas dispatch (see ops.block_jacobi_apply).

Distributed: contiguous row blocks never straddle the x-slab shards of
the distributed driver (shard boundaries are z-plane multiples), so
block-Jacobi is *exactly* shard-local — zero communication per apply, and
the driver builds it from the local slab operator
(:func:`repro.core.distributed.distributed_stencil_solve`).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .base import Preconditioner


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, repr=False)
class BlockJacobiPreconditioner(Preconditioner):
    """M^{-1} applied as pre-inverted dense diagonal blocks.

    ``inv_blocks`` is ``(nb, bs, bs)`` — or ``(1, bs, bs)`` for a block
    shared by all ``n // bs`` row blocks (constant-coefficient stencils).
    """

    inv_blocks: jax.Array

    name = "block_jacobi"

    @property
    def block_size(self) -> int:
        return self.inv_blocks.shape[-1]

    def apply(self, x: jax.Array) -> jax.Array:
        from repro.kernels import ref
        return ref.block_jacobi_apply(self.inv_blocks, x)

    def bind(self, sub):
        if getattr(sub, "kernel_backed", False):
            from repro.kernels import ops
            return functools.partial(ops.block_jacobi_apply, self.inv_blocks)
        return self.apply

    @staticmethod
    def from_operator(op, block_size: int | None = None
                      ) -> "BlockJacobiPreconditioner":
        """Extract + invert the diagonal blocks of ``op`` (setup time,
        host-side).  ``block_size`` must divide n; default: the stencil's
        ``nz`` (z-line blocks), else the largest divisor of n <= 64.

        Singular diagonal blocks (e.g. from empty rows) get the identity
        substituted — the same degrade-to-no-op guard as the Jacobi
        zero-diagonal case, instead of a raw LinAlgError at setup.
        """
        blocks = _extract_diag_blocks(op, block_size)
        return BlockJacobiPreconditioner(jnp.asarray(
            _inv_blocks_guarded(blocks), dtype=op.dtype))

    def tree_flatten(self):
        return (self.inv_blocks,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _inv_blocks_guarded(blocks: np.ndarray) -> np.ndarray:
    """Batched inverse with identity substituted for singular blocks."""
    try:
        return np.linalg.inv(blocks)
    except np.linalg.LinAlgError:
        inv = np.empty_like(blocks)
        for i, blk in enumerate(blocks):
            try:
                inv[i] = np.linalg.inv(blk)
            except np.linalg.LinAlgError:
                inv[i] = np.eye(blk.shape[0], dtype=blocks.dtype)
        return inv


def _default_block_size(n: int) -> int:
    # largest divisor of n up to 64, but strictly below n (a single
    # n-sized block would be a dense direct solve, not block-Jacobi)
    cap = min(64, max(1, n // 2))
    return next(s for s in range(cap, 0, -1) if n % s == 0)


def _extract_diag_blocks(op, block_size: int | None) -> np.ndarray:
    """(nb, bs, bs) diagonal blocks — (1, bs, bs) when all are identical."""
    from repro.core.linear_operator import (CSROperator, DenseOperator,
                                            ELLOperator, Stencil7Operator)

    if isinstance(op, Stencil7Operator):
        # z-lines are contiguous in the flattened index, so any bs | nz
        # yields the same tridiagonal block for every row block: c0 on the
        # diagonal, c5/c6 (z-/z+) on the off-diagonals.  ONE shared block.
        bs = op.nz if block_size is None else block_size
        if op.nz % bs:
            raise ValueError(f"block_size={bs} must divide nz={op.nz} "
                             "for Stencil7 block-Jacobi (z-line blocks)")
        c = np.asarray(op.c)
        blk = np.zeros((bs, bs), dtype=c.dtype)
        idx = np.arange(bs)
        blk[idx, idx] = c[0]
        blk[idx[1:], idx[1:] - 1] = c[5]
        blk[idx[:-1], idx[:-1] + 1] = c[6]
        return blk[None]

    n = op.shape[0]
    bs = _default_block_size(n) if block_size is None else block_size
    if n % bs:
        raise ValueError(f"block_size={bs} must divide n={n}")
    nb = n // bs

    if isinstance(op, DenseOperator):
        a = np.asarray(op.a)
        return a.reshape(nb, bs, nb, bs)[np.arange(nb), :, np.arange(nb), :]

    blocks = np.zeros((nb, bs, bs))
    if isinstance(op, ELLOperator):
        vals = np.asarray(op.values)
        cols = np.asarray(op.cols)
        rows = np.repeat(np.arange(n), vals.shape[1])
        vals, cols = vals.reshape(-1), cols.reshape(-1)
    elif isinstance(op, CSROperator):
        vals = np.asarray(op.data)
        cols = np.asarray(op.indices)
        rows = np.asarray(op.row_ids)
    else:
        raise TypeError(
            f"block_jacobi cannot extract diagonal blocks from "
            f"{type(op).__name__}; pass a Dense/CSR/ELL/Stencil7 operator "
            "or construct BlockJacobiPreconditioner directly")
    same = (rows // bs) == (cols // bs)
    np.add.at(blocks, (rows[same] // bs, rows[same] % bs, cols[same] % bs),
              vals[same])
    blocks = blocks.astype(np.asarray(vals).dtype)
    return blocks


def block_jacobi(op, block_size: int | None = None
                 ) -> BlockJacobiPreconditioner:
    """Factory: block-Jacobi with pre-inverted dense diagonal blocks."""
    return BlockJacobiPreconditioner.from_operator(op, block_size)
