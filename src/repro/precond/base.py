"""Preconditioner API: fixed linear M^{-1} operators for the Krylov core.

Left preconditioning throughout: a solver handed ``precond=`` solves

    M^{-1} A x = M^{-1} b

so the preconditioned residual norm is what ``relres``/``tol`` measure
(the standard convention; the returned ``x`` solves the original system).
Every preconditioner here is a *fixed linear* operator — mandatory for the
Krylov recurrences, and doubly so for the pipelined solvers whose recurred
A-images (q, w, l, g, s) silently assume the operator does not change
between iterations.

Why this is not a matvec wrapper
--------------------------------
The solvers accept the operator and the preconditioner *separately* and
compose them internally, for three reasons:

* substrate dispatch — a pre-composed closure would hide the operator
  type, so banded :class:`~repro.core.linear_operator.ELLOperator`s could
  no longer route to the Pallas SpMV kernels.  Threading ``precond=``
  keeps ``sub.as_matvec(op)`` / ``sub.as_block_matvec(op)`` dispatch
  intact and routes the M^{-1}-apply itself through the substrate
  (:meth:`repro.core.substrate.Substrate.as_precond_apply`).
* communication hiding — composed as ``M^{-1} ∘ A``, the apply lives
  *inside* the overlap window of the pipelined solvers: the fused dot
  phase still reads only ``{s, y, r, t_prev, rs}``, so the single
  reduction keeps NO dependency edge to the in-flight precond+matvec
  (exactly the role the M^{-1}-applies play in Cools & Vanroose's
  preconditioned pipelined BiCGStab, arXiv:1612.01395; asserted
  structurally in tests/test_substrate_parity.py and
  benchmarks/_overlap_child.py).
* synchronization count — no preconditioner here performs an inner
  product, so the per-iteration ``dot_reduce``/``psum`` count is
  unchanged by preconditioning (asserted in the sync-count tests and
  tests/_distributed_check.py).

``precond=`` accepts a :class:`Preconditioner` instance or a name from
:data:`PRECONDITIONERS` (``"jacobi"``, ``"block_jacobi"``, ``"neumann"``,
``"ssor"``) — names are built from the operator via its ``diagonal()`` /
structure, so they require an operator object, not a bare matvec callable.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

import jax


class Preconditioner:
    """Abstract fixed linear M^{-1}; subclasses are registered pytrees.

    ``apply(x)`` is the pure-jnp reference implementation and must be
    shape-polymorphic: ``(n,)`` vectors and ``(n, m)`` multi-RHS column
    blocks both map through the same preconditioner (per-column).

    ``bind(sub)`` returns the substrate-routed apply callable.  The base
    implementation returns :meth:`apply`; subclasses with a dedicated
    kernel (block-Jacobi) or matvec-based applies (Neumann) override it
    to consume the substrate's kernels (``sub.kernel_backed`` says whether
    the substrate is the Pallas one).
    """

    name = "abstract"

    def apply(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def bind(self, sub) -> Callable[[jax.Array], jax.Array]:
        return self.apply

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


def _factories():
    # lazy: the factory modules import operator classes from repro.core
    from .block_jacobi import block_jacobi
    from .jacobi import jacobi
    from .polynomial import neumann
    from .ssor import ssor
    return {"jacobi": jacobi, "block_jacobi": block_jacobi,
            "neumann": neumann, "ssor": ssor}


#: registry names accepted by ``precond=`` (resolved via the factories
#: in :func:`_factories`, each ``f(op) -> Preconditioner``)
PRECONDITIONERS = ("jacobi", "block_jacobi", "neumann", "ssor")

PrecondLike = Union[None, str, Preconditioner]


def validate_precond_spec(spec: PrecondLike, op) -> None:
    """Validate a precond spec without building it (cheap, eager).

    The bind-once session layer validates at bind time but builds
    lazily (a mesh-bound session rebuilds shard-locally and never needs
    the global build); the checks and messages here are the single
    source of truth for both paths.
    """
    if spec is None or isinstance(spec, Preconditioner):
        return
    if isinstance(spec, str):
        if spec not in PRECONDITIONERS:
            raise ValueError(
                f"unknown preconditioner {spec!r}; expected one of "
                f"{sorted(PRECONDITIONERS)} or a Preconditioner instance")
        if not hasattr(op, "diagonal"):
            raise TypeError(
                f"precond={spec!r} must be built from an operator object "
                "with .diagonal(); got a bare matvec callable — pass the "
                "operator itself, or construct the preconditioner "
                "explicitly (repro.precond.jacobi(op) etc.)")
        return
    raise TypeError(f"precond must be None, a name, or a Preconditioner; "
                    f"got {type(spec).__name__}")


def resolve_precond(spec: PrecondLike, op) -> Optional[Preconditioner]:
    """Resolve a precond spec: None / instance / registry name.

    Name specs are built from ``op``, which must be an operator object
    (``diagonal()`` etc.) — a bare matvec callable cannot seed a
    preconditioner and raises a TypeError naming the fix.
    """
    validate_precond_spec(spec, op)
    if spec is None or isinstance(spec, Preconditioner):
        return spec
    return _factories()[spec](op)


def operator_fingerprint(op, precond: PrecondLike = None) -> str:
    """Content hash identifying an operator (and optionally a precond spec).

    The implementation moved to :func:`repro.api.operator_fingerprint`
    (PR 5): the fingerprint is the key of the session cache in
    :mod:`repro.api`, which is the ONE place built preconditioners and
    compiled solver programs are memoized (the service registry consumes
    it).  This delegate keeps the historical import path working.
    """
    from repro.api import operator_fingerprint as _fp
    return _fp(op, precond)


def preconditioned_system(sub, op, b: jax.Array, precond: PrecondLike
                          ) -> Tuple[Callable, jax.Array]:
    """(matvec', b') of the left-preconditioned single-RHS system.

    ``matvec' = M^{-1} ∘ A`` with A from ``sub.as_matvec(op)`` (so operator
    dispatch to the Pallas SpMV survives) and the M^{-1}-apply from
    ``sub.as_precond_apply`` — inside the pipelined solvers the whole
    composite is the in-flight compute the single reduction overlaps.
    """
    mv = sub.as_matvec(op)
    pc = resolve_precond(precond, op)
    if pc is None:
        return mv, b
    papply = sub.as_precond_apply(pc)
    return (lambda x: papply(mv(x))), papply(b)


def wrap_block_preconditioned(sub, bmv: Callable, B: jax.Array,
                              precond: PrecondLike, op
                              ) -> Tuple[Callable, jax.Array]:
    """Block (multi-RHS) analogue of :func:`preconditioned_system`.

    ``bmv`` is the already-lifted ``(n, m) -> (n, m)`` block matvec (the
    substrate's, or the distributed driver's halo matvec); the
    preconditioner apply is shape-polymorphic so the same bound callable
    serves the column block.
    """
    pc = resolve_precond(precond, op)
    if pc is None:
        return bmv, B
    papply = sub.as_precond_apply(pc)
    return (lambda x: papply(bmv(x))), papply(B)


def preconditioned_matvec(op, precond) -> Callable:
    """Compose ``M^{-1} ∘ A`` as a bare callable.

    Deprecated entry point (kept for the historical
    ``repro.core.linear_operator`` API): prefer passing ``precond=`` to a
    solver, which keeps operator dispatch and routes the apply through
    the compute substrate.
    """
    from repro.core.linear_operator import as_matvec
    mv = as_matvec(op)
    if precond is None:
        return mv
    return lambda x: precond.apply(mv(x))
