"""repro.precond — the preconditioning subsystem.

Fixed linear M^{-1} operators threaded through every solver entry point
in :mod:`repro.core` via ``precond=`` (left preconditioning: the solvers
run on M^{-1} A with M^{-1} b, so ``relres``/``tol`` measure the
preconditioned residual).  See :mod:`repro.precond.base` for why this is
threaded through the solvers rather than composed as a matvec wrapper
(substrate dispatch, communication hiding, sync-count preservation).

Preconditioners (all pytrees; ``(n,)`` and ``(n, m)`` multi-RHS applies):

* :func:`jacobi`        — diag(A)^{-1}; elementwise, fused by XLA.
* :func:`block_jacobi`  — pre-inverted dense diagonal blocks, applied by
  the Pallas batched block-apply kernel on the pallas substrate
  (:mod:`repro.kernels.precond_apply`); exactly shard-local in the
  distributed driver.
* :func:`neumann`       — degree-d truncated Neumann polynomial; pure
  matvec arithmetic, rides the substrate's SpMV kernels.
* :func:`ssor`          — truncated-Neumann SSOR for Stencil7 operators.

``precond=`` also accepts these names as strings ("jacobi",
"block_jacobi", "neumann", "ssor") when the solver is handed an operator
object to build from.
"""
from .base import (PRECONDITIONERS, Preconditioner, PrecondLike,
                   operator_fingerprint, preconditioned_matvec,
                   preconditioned_system, resolve_precond,
                   wrap_block_preconditioned)
from .block_jacobi import BlockJacobiPreconditioner, block_jacobi
from .jacobi import JacobiPreconditioner, jacobi
from .polynomial import NeumannPreconditioner, neumann
from .ssor import SSORPreconditioner, ssor

__all__ = [
    "Preconditioner", "PrecondLike", "PRECONDITIONERS",
    "resolve_precond", "preconditioned_system",
    "wrap_block_preconditioned", "preconditioned_matvec",
    "operator_fingerprint",
    "JacobiPreconditioner", "jacobi",
    "BlockJacobiPreconditioner", "block_jacobi",
    "NeumannPreconditioner", "neumann",
    "SSORPreconditioner", "ssor",
]
