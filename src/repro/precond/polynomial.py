"""Neumann / polynomial preconditioner: M^{-1} = p_d(A).

Truncated Neumann series of the Jacobi-split inverse: with D = diag(A)
and G = I - omega D^{-1} A,

    M^{-1} x = (I + G + G^2 + ... + G^d) * omega D^{-1} x

which converges to A^{-1} as d grows whenever rho(G) < 1 (diagonally
dominant systems).  The apply is *pure matvec arithmetic* — d extra
operator applications plus diagonal scalings, no triangular solves and no
inner products — so on the pallas substrate it rides the existing SpMV
kernels unmodified (banded ELL operators dispatch to
``spmv_ell``/``spmv_ell_batched``), and in the pipelined solvers the whole
polynomial evaluation sits inside the overlap window of the single
reduction: the classic "more hidden compute per iteration" trade the
communication-hiding methods are built for.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import Preconditioner
from .jacobi import JacobiPreconditioner


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, repr=False)
class NeumannPreconditioner(Preconditioner):
    """Degree-``degree`` truncated Neumann series of ``op``'s inverse.

    Holds the operator itself (a pytree) so the bound apply can route the
    series matvecs through the substrate — single-RHS and ``(n, m)``
    column blocks both work (the block path uses the substrate's block
    matvec, e.g. the block-ELL kernel).
    """

    op: object
    inv_diag: jax.Array
    degree: int = 2
    omega: float = 1.0

    name = "neumann"

    def _apply_with(self, mv, x: jax.Array) -> jax.Array:
        d = self.inv_diag if x.ndim == 1 else self.inv_diag[:, None]
        z = self.omega * d * x
        y = z
        v = z
        for _ in range(self.degree):
            v = v - self.omega * d * mv(v)      # v <- G v
            y = y + v
        return y

    def apply(self, x: jax.Array) -> jax.Array:
        from repro.core.linear_operator import as_matvec
        mv = as_matvec(self.op)
        if x.ndim == 2:
            from repro.core.multirhs import batched_matvec
            mv = batched_matvec(mv)
        return self._apply_with(mv, x)

    def bind(self, sub):
        mv1 = sub.as_matvec(self.op)
        mvb = sub.as_block_matvec(self.op)

        def apply(x):
            return self._apply_with(mv1 if x.ndim == 1 else mvb, x)
        return apply

    @staticmethod
    def from_operator(op, degree: int = 2, omega: float = 1.0
                      ) -> "NeumannPreconditioner":
        return NeumannPreconditioner(
            op, JacobiPreconditioner.from_operator(op).inv_diag,
            degree, omega)

    def tree_flatten(self):
        return (self.op, self.inv_diag), (self.degree, self.omega)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def neumann(op, degree: int = 2, omega: float = 1.0
            ) -> NeumannPreconditioner:
    """Factory: degree-``degree`` Neumann polynomial preconditioner."""
    return NeumannPreconditioner.from_operator(op, degree, omega)
