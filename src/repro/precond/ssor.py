"""SSOR preconditioner for the matrix-free 7-point stencil operator.

M_SSOR = 1/(omega(2-omega)) (D + omega L) D^{-1} (D + omega U) with the
stencil's natural splitting: D = c0 I, L the lower shifts (x-, y-, z-) and
U the upper shifts (x+, y+, z+).  Exact triangular solves are a 3-D
wavefront recurrence — hostile to SIMD/TPU execution — so the two solves
are applied as truncated Neumann expansions

    (D + omega L)^{-1}  ~=  (sum_k (-omega D^{-1} L)^k) D^{-1},  k <= terms

(van der Vorst's "truncated Neumann SSOR"; L and U are nilpotent-ish
shift operators so few terms capture most of the sweep).  The result is a
FIXED linear operator built entirely from stencil shifts — parallel,
jit/vmap-safe, shape-polymorphic over trailing ``(n, m)`` RHS columns,
and free of inner products, so the solver's synchronization count is
untouched.  No dedicated Pallas kernel: the applies are the same
pad+shift pattern as ``Stencil7Operator.matvec``, which XLA already fuses
into a handful of streaming passes (noted in the support matrix).

Distributed note: built from the *local* slab operator this becomes the
shard-local (zero-Dirichlet at slab boundaries) SSOR — an additive-
Schwarz-flavored approximation that needs no halo traffic (see
repro.core.distributed).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import Preconditioner


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, repr=False)
class SSORPreconditioner(Preconditioner):
    """Truncated-Neumann SSOR for a 7-point stencil (c, nx, ny, nz)."""

    c: jax.Array        # the 7 stencil coefficients
    nx: int
    ny: int
    nz: int
    omega: float = 1.0
    terms: int = 2      # Neumann terms per triangular solve

    name = "ssor"

    def _shift_sum(self, u, lower: bool):
        """L u (lower=True) or U u on the (nx, ny, nz, ...) grid."""
        c = self.c
        zx = jnp.zeros_like(u[:1])
        zy = jnp.zeros_like(u[:, :1])
        zz = jnp.zeros_like(u[:, :, :1])
        if lower:
            um = jnp.concatenate([zx, u[:-1]], axis=0)
            vm = jnp.concatenate([zy, u[:, :-1]], axis=1)
            wm = jnp.concatenate([zz, u[:, :, :-1]], axis=2)
            return c[1] * um + c[3] * vm + c[5] * wm
        up = jnp.concatenate([u[1:], zx], axis=0)
        vp = jnp.concatenate([u[:, 1:], zy], axis=1)
        wp = jnp.concatenate([u[:, :, 1:], zz], axis=2)
        return c[2] * up + c[4] * vp + c[6] * wp

    def _tri_solve(self, u, lower: bool):
        """Truncated Neumann series for (D + omega T)^{-1} u."""
        d_inv = 1.0 / self.c[0]
        v = d_inv * u
        acc = v
        for _ in range(self.terms):
            v = -self.omega * d_inv * self._shift_sum(v, lower)
            acc = acc + v
        return acc

    def apply(self, x: jax.Array) -> jax.Array:
        u = x.reshape(self.nx, self.ny, self.nz, *x.shape[1:])
        w = self._tri_solve(u, lower=True)
        w = self.c[0] * w                         # D
        w = self._tri_solve(w, lower=False)
        w = self.omega * (2.0 - self.omega) * w
        return w.reshape(x.shape)

    @staticmethod
    def from_operator(op, omega: float = 1.0, terms: int = 2
                      ) -> "SSORPreconditioner":
        from repro.core.linear_operator import Stencil7Operator
        if not isinstance(op, Stencil7Operator):
            raise TypeError(
                "ssor is the Stencil7Operator preconditioner; got "
                f"{type(op).__name__} (use jacobi/block_jacobi/neumann)")
        return SSORPreconditioner(op.c, op.nx, op.ny, op.nz, omega, terms)

    def tree_flatten(self):
        return (self.c,), (self.nx, self.ny, self.nz, self.omega, self.terms)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def ssor(op, omega: float = 1.0, terms: int = 2) -> SSORPreconditioner:
    """Factory: truncated-Neumann SSOR for a Stencil7 operator."""
    return SSORPreconditioner.from_operator(op, omega, terms)
