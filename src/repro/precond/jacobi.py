"""Jacobi (diagonal) preconditioner: M^{-1} = diag(A)^{-1}.

The cheapest preconditioner and the one that matters most on badly
row-scaled systems (the ``hard_nonsym`` family, whose 10^±(scale/2) row
scaling is exactly what diag^{-1} removes).  The apply is a pure
elementwise multiply — memory-bound and trivially fused by XLA into the
surrounding matvec epilogue on either substrate, so no dedicated Pallas
kernel exists (noted in the support matrix in repro/core/_common.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import Preconditioner


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, repr=False)
class JacobiPreconditioner(Preconditioner):
    """Left Jacobi preconditioner M^{-1} = diag(A)^{-1}.

    Historically lived in ``repro.core.linear_operator`` (unused by any
    solver); it is now part of the :mod:`repro.precond` subsystem and
    threads through every solver entry point via ``precond=``.
    """

    inv_diag: jax.Array

    name = "jacobi"

    def apply(self, x: jax.Array) -> jax.Array:
        d = self.inv_diag if x.ndim == 1 else self.inv_diag[:, None]
        return d * x

    @staticmethod
    def from_operator(op) -> "JacobiPreconditioner":
        """Build from ``op.diagonal()``.

        The zero-diagonal guard is dtype-preserving: the substitute 1 and
        the reciprocal are formed in the diagonal's own dtype, so an fp64
        operator under the x64 conftest yields an fp64 (non-weak-typed)
        ``inv_diag`` instead of a weakly-typed ``1.0 / d`` promotion.
        """
        d = op.diagonal()
        one = jnp.ones((), d.dtype)
        return JacobiPreconditioner(jnp.where(d != 0, one / d, one))

    def tree_flatten(self):
        return (self.inv_diag,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def jacobi(op) -> JacobiPreconditioner:
    """Factory: Jacobi preconditioner from any operator with ``diagonal()``."""
    return JacobiPreconditioner.from_operator(op)
