"""The binding-matrix audit: statically prove the contracts everywhere.

``run_audit`` sweeps every cell of the scenario matrix — all 7 methods x
{jnp, pallas} x {guard on/off} x {precond on/off}, the open-loop service
chunk, and an all-devices mesh smoke — through :func:`trace_binding` and
the contract passes, then compares each finding against the paper's
expected outcome for that cell.  Everything is TRACED, never executed:
zero solver runs, zero compiles.

The baseline methods are the audit's negative controls: BiCGStab / CGS /
GPBi-CG *should* fail ``one_reduction_per_iteration`` and
``overlap_edge_free`` — that differential is the paper's claim, and an
analyzer that cannot see it proves nothing.  The audit therefore fails
on DEVIATIONS from the expected matrix (a pipelined method regressing to
two reductions, OR a baseline suddenly "passing" — which would mean the
probe lost its anchor), not on expected violations.

Artifact: ``experiments/contract_audit.json`` (schema
``repro.analysis/contract_audit/v1``), consumed by the golden-snapshot
test and uploaded by the CI ``analysis-audit`` job.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import SOLVERS
from repro.core.linear_operator import Stencil7Operator

from .passes import _KERNEL_PHASES, run_passes
from .report import OK, SKIPPED, VIOLATION, BindingSpec, ContractReport
from .trace import trace_binding

__all__ = ["ARTIFACT_SCHEMA", "expected_outcomes", "audit_specs",
           "run_audit", "METHOD_ORDER"]

ARTIFACT_SCHEMA = "repro.analysis/contract_audit/v1"

#: audit row order: the paper's methods first, then the baselines
METHOD_ORDER = ("p-bicgsafe", "p-bicgsafe-rr", "ssbicgsafe2",
                "p-bicgstab", "bicgstab", "gpbicg", "cgs")

#: methods whose single fused phase ALSO hides behind the matvec
PIPELINED = frozenset({"p-bicgsafe", "p-bicgsafe-rr"})
#: methods with the one fused (9[, m]) reduction phase per iteration
FUSED = PIPELINED | frozenset({"ssbicgsafe2"})

SUBSTRATE_ORDER = ("jnp", "pallas")


def expected_outcomes(spec: BindingSpec) -> Dict[str, str]:
    """The paper-expected status of every contract for one cell.

    Pipelined BiCGSafe methods satisfy the full contract set; sequential
    ssBiCGSafe2 fuses the dots but its reduction consumes the matvec
    (one sync, no hiding); the BiCGStab/GPBi-CG family keeps 2-3
    scattered reductions — the negative controls.
    """
    exp = {}
    exp["one_reduction_per_iteration"] = \
        OK if spec.method in FUSED else VIOLATION
    # a 1-device mesh has no halo ppermutes: every reduction is
    # trivially edge-free there, even for the sequential methods
    trivial_mesh = spec.binding == "mesh" and spec.mesh_shape is not None \
        and all(d == 1 for d in spec.mesh_shape)
    exp["overlap_edge_free"] = \
        OK if (spec.method in PIPELINED or trivial_mesh) else VIOLATION
    exp["single_psum_sharded"] = SKIPPED if spec.binding != "mesh" else (
        OK if spec.method in FUSED else VIOLATION)
    exp["kernel_backed"] = OK if (spec.substrate == "pallas"
                                  and spec.method in _KERNEL_PHASES) \
        else SKIPPED
    exp["dtype_flow"] = OK
    return exp


def _audit_operator(nx=8, ny=6, nz=6, dtype=None):
    """A non-symmetric convection-diffusion stencil, built directly so
    the audit performs no eager operator application."""
    import numpy as np
    dtype = dtype or jax.dtypes.canonicalize_dtype(np.float64)
    c = jnp.array([6.5, -1.5, -1.0, -1.25, -1.0, -1.0, -1.0], dtype=dtype)
    return Stencil7Operator(c, nx, ny, nz)


def audit_specs(quick: bool = False) -> List[dict]:
    """The trace_binding kwargs for every audit cell — derived from the
    scenario registry (:mod:`repro.scenarios.cells`).

    The dense acceptance matrix is identical in quick and full mode
    (7 methods x 2 substrates x guard x precond + open-loop; full mode
    widens the preconditioner axis to the kernel-dispatching ones), and
    every REGISTERED scenario contributes one extra row carrying its
    operator class and its plugin's expected-outcome overrides — so a
    new scenario (or a new operator-class plugin) lands under the
    contract audit by registration alone.
    """
    # lazy both ways: neither package imports the other at module scope
    from repro.scenarios import contract_cells
    return contract_cells(quick=quick)


def _mesh_specs() -> List[dict]:
    """Mesh smoke cells (sharded drivers; psum count is mesh-size
    independent, so any device count proves the contract)."""
    return [
        dict(method="p-bicgsafe", binding="mesh", substrate="jnp",
             guard=False, precond=None),
        dict(method="p-bicgsafe", binding="mesh", substrate="jnp",
             guard=True, precond=None),
        # shard-local preconditioning must add ZERO collectives
        dict(method="p-bicgsafe", binding="mesh", substrate="jnp",
             guard=False, precond="jacobi"),
        dict(method="ssbicgsafe2", binding="mesh", substrate="jnp",
             guard=False, precond=None),
        dict(method="bicgstab", binding="mesh", substrate="jnp",
             guard=False, precond=None),
    ]


def _build_mesh():
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    return Mesh(np.array(devs).reshape(len(devs)), ("x",))


def _mesh_operator(ndev: int):
    # x-slab sharding needs nx % ndev == 0; 8 covers 1/2/4/8 devices
    nx = 8 if 8 % ndev == 0 else 8 * ndev
    return _audit_operator(nx=nx, ny=6, nz=6)


def run_audit(quick: bool = False,
              mesh_smoke: bool = True,
              contracts: Optional[Sequence[str]] = None) -> dict:
    """Sweep the matrix; return the artifact dict (schema
    ``repro.analysis/contract_audit/v1``).  ``artifact["ok"]`` is False
    iff any cell deviated from :func:`expected_outcomes`."""
    op = _audit_operator()
    cells = audit_specs(quick=quick)
    reports: List[ContractReport] = []
    records: List[dict] = []
    deviations: List[dict] = []

    def run_cell(kw, operator, mesh=None):
        # registry-driven rows resolve their operator through the
        # scenario plugin (unregistered classes fail loudly there) and
        # merge the plugin's declared expected-outcome deltas
        if kw.get("operator_class"):
            from repro.scenarios import build_problem
            operator = build_problem(kw["operator_class"],
                                     **(kw.get("operator_params") or {}))[0]
        tb = trace_binding(kw["method"], operator, binding=kw["binding"],
                           substrate=kw["substrate"], guard=kw["guard"],
                           precond=kw["precond"], m=3, mesh=mesh)
        rep = run_passes(tb, names=contracts)
        exp = expected_outcomes(tb.spec)
        exp.update(kw.get("expected") or {})
        devs = []
        for f in rep.findings:
            want = exp.get(f.contract)
            if want is not None and f.status != want:
                devs.append({"binding": tb.spec.label,
                             "scenario": kw.get("scenario"),
                             "contract": f.contract,
                             "expected": want, "actual": f.status,
                             "detail": f.detail})
        reports.append(rep)
        deviations.extend(devs)
        rec = rep.to_dict()
        if kw.get("scenario"):
            rec["scenario"] = kw["scenario"]
            rec["operator_class"] = kw["operator_class"]
        rec["expected"] = {f.contract: exp.get(f.contract)
                          for f in rep.findings}
        rec["deviations"] = devs
        records.append(rec)

    for kw in cells:
        run_cell(kw, op)
    n_mesh = 0
    if mesh_smoke:
        mesh = _build_mesh()
        mop = _mesh_operator(len(jax.devices()))
        for kw in _mesh_specs():
            run_cell(kw, mop, mesh=mesh)
            n_mesh += 1

    # the method x substrate contract matrix (aggregated over guard /
    # precond cells; a disagreement inside one aggregate cell surfaces
    # as "mixed" — itself a deviation signal)
    contract_names = []
    for r in reports:
        for f in r.findings:
            if f.contract not in contract_names:
                contract_names.append(f.contract)
    matrix: Dict[str, Dict[str, str]] = {}
    for r in reports:
        if r.spec.binding == "mesh":
            continue
        key = f"{r.spec.method}/{r.spec.substrate}"
        cell = matrix.setdefault(key, {})
        for f in r.findings:
            prev = cell.get(f.contract)
            cell[f.contract] = f.status if prev in (None, f.status) \
                else "mixed"

    return {
        "schema": ARTIFACT_SCHEMA,
        "jax_version": jax.__version__,
        "quick": bool(quick),
        "n_devices": len(jax.devices()),
        "n_cells": len(reports),
        "n_mesh_cells": n_mesh,
        "n_scenario_cells": sum(1 for c in cells if c.get("scenario")),
        "methods": list(METHOD_ORDER),
        "substrates": list(SUBSTRATE_ORDER),
        "contracts": contract_names,
        "matrix": matrix,
        "reports": records,
        "deviations": deviations,
        "ok": not deviations,
    }


def audit_table(artifact: dict) -> str:
    """Render the human-readable contract table for an audit artifact."""
    lines = ["contract matrix (method/substrate, aggregated over "
             "guard x precond cells):", ""]
    contracts = artifact["contracts"]
    cellmap = {OK: "pass", VIOLATION: "FAIL", SKIPPED: "-",
               "mixed": "MIXED"}
    headers = ["method/substrate"] + contracts
    rows = []
    for key, cell in artifact["matrix"].items():
        rows.append([key] + [cellmap.get(cell.get(c, SKIPPED), "?")
                             for c in contracts])
    widths = [max(len(h), *(len(r[i]) for r in rows))
              for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    lines.append(fmt.format(*headers))
    lines.append(fmt.format(*("-" * w for w in widths)))
    lines += [fmt.format(*r) for r in rows]
    lines.append("")
    lines.append(f"{artifact['n_cells']} cells traced "
                 f"({artifact['n_mesh_cells']} mesh, "
                 f"{artifact['n_devices']} device(s)); "
                 + ("all outcomes match the paper-expected matrix"
                    if artifact["ok"] else
                    f"{len(artifact['deviations'])} DEVIATION(S) from "
                    "the expected matrix"))
    for d in artifact["deviations"]:
        lines.append(f"  !! {d['binding']}: {d['contract']} expected "
                     f"{d['expected']}, got {d['actual']} — {d['detail']}")
    return "\n".join(lines)
