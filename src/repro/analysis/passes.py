"""The contract passes: the paper's invariants as named static checks.

Each pass consumes a :class:`~repro.analysis.trace.TracedBinding` and
returns one :class:`~repro.analysis.report.Finding`.  The registry
:data:`PASSES` is ordered and name-addressable; :func:`run_passes`
applies every applicable pass and packages a
:class:`~repro.analysis.report.ContractReport`.

The five contracts (Huynh & Suito 2021; Cools & Vanroose 1612.01395;
Cools 1809.01948):

* ``one_reduction_per_iteration`` — the while body holds EXACTLY ONE
  fused reduction phase, carrying the whole (9, m) partial block —
  (11, m) when the guard rides along — never a second sync.
* ``overlap_edge_free``           — that reduction transitively consumes
  NO output of the in-flight matvec (halo ``ppermute`` on a mesh), so
  communication can hide behind computation.
* ``single_psum_sharded``         — on a mesh the reduction lowers to
  ONE ``psum`` per iteration and nothing else introduces collectives
  (shard-local preconditioners must cost zero extra).
* ``kernel_backed``               — pallas-substrate bindings dispatch
  the hot-loop phases to Pallas kernels (``pallas_call`` in the body),
  no silent jnp fallback.
* ``dtype_flow``                  — no precision-losing float cast inside
  the recurrence chain (the PR-2 class of bug: an f32/bf16 downcast in
  an operator or preconditioner closure silently breaks recurrence
  linearity).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence

from .jaxpr_tools import (count_prim, find_prim_eqns, subjaxprs,
                          transitive_inputs)
from .report import (OK, SKIPPED, VIOLATION, ContractReport, Finding,
                     eqn_provenance)
from .trace import TracedBinding

__all__ = ["PASSES", "contract_pass", "run_passes",
            "reduction_consumes_matvec"]

#: ordered registry: name -> (applies(spec) predicate, pass fn)
PASSES: "OrderedDict[str, tuple]" = OrderedDict()


def contract_pass(name: str, applies: Optional[Callable] = None):
    """Register a contract pass under ``name`` (decorator)."""
    def deco(fn):
        PASSES[name] = ((applies or (lambda spec: True)), fn)
        return fn
    return deco


def run_passes(tb: TracedBinding,
               names: Optional[Sequence[str]] = None) -> ContractReport:
    """Run the (named subset of the) registered passes over one traced
    binding; inapplicable passes report ``skipped``."""
    findings: List[Finding] = []
    for name, (applies, fn) in PASSES.items():
        if names is not None and name not in names:
            continue
        if not applies(tb.spec):
            findings.append(Finding(name, SKIPPED, "not applicable to "
                                    f"{tb.spec.binding}/{tb.spec.substrate}"))
            continue
        findings.append(fn(tb))
    return ContractReport(spec=tb.spec, findings=tuple(findings))


# ---------------------------------------------------------------------------
# pass bodies
# ---------------------------------------------------------------------------

def _fused_leading_dim(spec) -> int:
    return 11 if spec.guard_effective else 9


@contract_pass("one_reduction_per_iteration")
def one_reduction_per_iteration(tb: TracedBinding) -> Finding:
    """EXACTLY ONE reduction phase per iteration, carrying the whole
    (9[, m]) — guarded: (11[, m]) — fused partial block."""
    name = "one_reduction_per_iteration"
    if tb.body is None:
        return Finding(name, VIOLATION, "no while loop found in the "
                       "traced program")
    reds = tb.reduce_eqns()
    if len(reds) != 1:
        return Finding(
            name, VIOLATION,
            f"{len(reds)} reduction phases per iteration (contract: 1)",
            tuple(eqn_provenance(e) for e in reds))
    shape = tuple(reds[0].invars[0].aval.shape)
    want = _fused_leading_dim(tb.spec)
    if shape[:1] != (want,):
        return Finding(
            name, VIOLATION,
            f"the single reduction carries {shape}, not the fused "
            f"({want}[, m]) partial block",
            (eqn_provenance(reds[0]),))
    return Finding(name, OK,
                   f"one fused {shape} reduction per iteration",
                   (eqn_provenance(reds[0]),))


def reduction_consumes_matvec(tb: TracedBinding):
    """Shared overlap core: does ANY reduction phase in the while body
    transitively consume the in-flight matvec (matvec tag locally, halo
    ``ppermute`` on a mesh)?  Returns ``(edge_exists, detail,
    provenance)`` or raises ValueError when the probe found nothing to
    anchor on."""
    if tb.body is None:
        raise ValueError("no while loop found in the traced program")
    if tb.spec.binding == "mesh":
        # the dependency walk is scoped to ONE jaxpr (variables are
        # jaxpr-local), so anchor on the body-level psum/ppermute eqns —
        # where the jit=False sharded drivers place them
        reds = [e for e in tb.body.eqns if e.primitive.name == "psum"]
        if not reds:
            raise ValueError("no body-level psum found in the while body")
        producer_outs = set()
        for eqn in tb.body.eqns:
            if eqn.primitive.name == "ppermute":
                producer_outs.update(eqn.outvars)
        producer_kind = "halo ppermute"
        if not producer_outs:
            return (False, "no halo ppermutes in the body (single-device "
                    "mesh); reduction trivially edge-free", ())
    else:
        reds = tb.reduce_eqns()
        if not reds:
            raise ValueError("no reduction phase found in the while body")
        producer_outs = set()
        for eqn in tb.matvec_tag_eqns():
            producer_outs.update(eqn.outvars)
        producer_kind = "matvec"
        if not producer_outs:
            raise ValueError("no matvec tag found in the while body")
    for red in reds:
        needed = transitive_inputs(tb.body, red)
        if needed & producer_outs:
            return (True,
                    f"a reduction transitively consumes the in-flight "
                    f"{producer_kind} output",
                    (eqn_provenance(red),))
    return (False,
            f"no dependency edge from any reduction to the in-flight "
            f"{producer_kind} ({len(reds)} reduction(s), "
            f"{len(producer_outs)} tagged output(s))",
            tuple(eqn_provenance(e) for e in reds))


@contract_pass("overlap_edge_free")
def overlap_edge_free(tb: TracedBinding) -> Finding:
    """The reduction has NO dependency edge to the in-flight matvec —
    the communication-hiding property itself."""
    name = "overlap_edge_free"
    try:
        edge, detail, prov = reduction_consumes_matvec(tb)
    except ValueError as e:
        return Finding(name, VIOLATION, f"probe inconclusive: {e}")
    return Finding(name, VIOLATION if edge else OK, detail, prov)


#: collectives that must NOT appear in a sharded iteration body beyond
#: the single psum (halo ppermutes are the matvec's and are allowed)
_FORBIDDEN_COLLECTIVES = ("all_gather", "all_to_all", "reduce_scatter",
                          "pmax", "pmin", "pgather")


@contract_pass("single_psum_sharded",
               applies=lambda spec: spec.binding == "mesh")
def single_psum_sharded(tb: TracedBinding) -> Finding:
    """On a mesh: ONE psum per iteration — the fused block — and zero
    other collectives (shard-local preconditioners add none)."""
    name = "single_psum_sharded"
    if tb.body is None:
        return Finding(name, VIOLATION, "no while loop found")
    psums = find_prim_eqns(tb.body, "psum")
    if len(psums) != 1:
        return Finding(name, VIOLATION,
                       f"{len(psums)} psums per iteration (contract: 1)",
                       tuple(eqn_provenance(e) for e in psums))
    extra = [p for p in _FORBIDDEN_COLLECTIVES
             if count_prim(tb.body, p) > 0]
    if extra:
        return Finding(name, VIOLATION,
                       f"extra collectives in the iteration body: {extra}")
    shape = tuple(psums[0].invars[0].aval.shape)
    want = _fused_leading_dim(tb.spec)
    if shape[:1] != (want,):
        return Finding(name, VIOLATION,
                       f"the psum carries {shape}, not the fused "
                       f"({want}[, m]) block", (eqn_provenance(psums[0]),))
    return Finding(name, OK, f"one {shape} psum per iteration, no other "
                   "collectives", (eqn_provenance(psums[0]),))


#: kernel-backed fused phases per method on the pallas substrate: the
#: pipelined variants run fused-dots AND the fused-axpy update phase as
#: kernels; sequential ssBiCGSafe2 has only the fused-dots phase.  The
#: BiCGStab/GPBi-CG family's 1-5 dot phases intentionally stay jnp (not
#: the paper's hot path), so the contract does not apply to them.
_KERNEL_PHASES = {"p-bicgsafe": 2, "p-bicgsafe-rr": 2, "ssbicgsafe2": 1}


@contract_pass("kernel_backed",
               applies=lambda spec: spec.substrate == "pallas"
               and spec.method in _KERNEL_PHASES)
def kernel_backed(tb: TracedBinding) -> Finding:
    """Pallas-substrate bindings dispatch the hot-loop phases to Pallas
    kernels: the while body must contain the method's fused-phase
    ``pallas_call``s (plus the block-Jacobi apply kernel when that
    preconditioner is bound) — a silent jnp fallback shows up here as a
    missing kernel."""
    name = "kernel_backed"
    if tb.body is None:
        return Finding(name, VIOLATION, "no while loop found")
    n_calls = count_prim(tb.body, "pallas_call")
    want = _KERNEL_PHASES.get(tb.spec.method, 1) + tb.spec.precond_kernels
    if n_calls < want:
        return Finding(name, VIOLATION,
                       f"{n_calls} pallas_call(s) in the iteration body "
                       f"(contract: >= {want} fused-phase kernel(s)"
                       + ("; + block-Jacobi apply"
                          if tb.spec.precond_kernels else "")
                       + ") — silent jnp fallback")
    return Finding(name, OK,
                   f"{n_calls} pallas_call(s) back the iteration body")


def _walk_converts(jaxpr, acc):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "convert_element_type":
            acc.append(eqn)
        for sub in subjaxprs(eqn):
            _walk_converts(sub, acc)
    return acc


@contract_pass("dtype_flow")
def dtype_flow(tb: TracedBinding) -> Finding:
    """No precision-losing float cast inside the recurrence chain.

    Pipelined recurrences replace the true residual with recurred
    vectors; a hidden downcast (f64->f32, f32->bf16) inside the operator
    or preconditioner closure breaks their linearity and lets the
    recurred residual drift from the true one — the exact class of bug
    PR 2 root-caused in the GGN path.  Statically: the while body must
    contain no ``convert_element_type`` from a wider float to a narrower
    one."""
    import numpy as np
    name = "dtype_flow"
    if tb.body is None:
        return Finding(name, VIOLATION, "no while loop found")
    bad = []
    for eqn in _walk_converts(tb.body, []):
        src = np.dtype(eqn.invars[0].aval.dtype)
        dst = np.dtype(eqn.params.get("new_dtype"))
        if (src.kind == "f" and dst.kind == "f"
                and dst.itemsize < src.itemsize):
            bad.append((str(src), str(dst), eqn))
    if bad:
        return Finding(
            name, VIOLATION,
            "precision-losing float cast(s) in the recurrence chain: "
            + ", ".join(f"{s}->{d}" for s, d, _ in bad),
            tuple(eqn_provenance(e) for _, _, e in bad))
    return Finding(name, OK, "no precision-losing float casts in the "
                   "iteration body")
