"""``python -m repro.analysis audit`` — the contract audit CLI.

Sweeps the full binding matrix through the contract passes (tracing
only, zero solver executions), prints the human-readable contract
table, writes ``experiments/contract_audit.json``, and exits non-zero
when any cell deviates from the paper-expected outcome matrix.  This is
the CI ``analysis-audit`` job.

The cell list is derived from the scenario registry
(:mod:`repro.scenarios`): every registered scenario contributes one
contract row on top of the dense acceptance matrix, and ``--scenarios
FILE`` registers extra scenario dicts for this run.  Scenario problems
— an unregistered operator class, an unknown preconditioner — exit
with a one-line message (exit code 2), never a traceback.
"""
import argparse
import json
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)
    audit_p = sub.add_parser(
        "audit", help="statically verify the contract matrix")
    audit_p.add_argument("--quick", action="store_true",
                         help="core matrix only (CI mode): skip the "
                         "extra kernel-dispatching preconditioner cells")
    audit_p.add_argument("--out", default="experiments/contract_audit.json",
                         help="artifact path (default: %(default)s)")
    audit_p.add_argument("--no-mesh", action="store_true",
                         help="skip the sharded mesh smoke cells")
    audit_p.add_argument("--devices", type=int, default=8,
                         help="fake host devices for the mesh smoke "
                         "(default: %(default)s; set BEFORE jax imports)")
    audit_p.add_argument("--scenarios", default=None, metavar="FILE",
                         help="JSON file with extra scenario dicts to "
                         "register before the audit (each becomes one "
                         "contract row)")
    args = ap.parse_args(argv)

    # The mesh smoke needs the fake devices staged before the XLA
    # backend initializes — but ``python -m repro.analysis`` imports the
    # repro package (and with it jax) before this file runs.  Stage the
    # flag and re-exec once if the backend already pinned the device
    # count.
    if args.devices > 1 and not args.no_mesh:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count"
                f"={args.devices}").strip()
    import jax
    jax.config.update("jax_enable_x64", True)
    if args.devices > 1 and not args.no_mesh \
            and len(jax.devices()) < args.devices \
            and os.environ.get("_REPRO_AUDIT_REEXEC") != "1":
        os.environ["_REPRO_AUDIT_REEXEC"] = "1"
        os.execv(sys.executable,
                 [sys.executable, "-m", "repro.analysis"]
                 + list(argv if argv is not None else sys.argv[1:]))

    from repro.analysis.audit import audit_table, run_audit
    from repro.scenarios import ScenarioError

    try:
        if args.scenarios:
            from repro.scenarios.__main__ import _register_file
            _register_file(args.scenarios)
        artifact = run_audit(quick=args.quick,
                             mesh_smoke=not args.no_mesh)
    except ScenarioError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    out = args.out
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
            f.write("\n")
    print(audit_table(artifact))
    if out:
        print(f"\nartifact: {out}")
    return 0 if artifact["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
