"""repro.analysis — the static contract-verifier for the paper's invariants.

The paper's value proposition is *structural*: one fused inner-product
phase per iteration, with no dependency edge from that reduction to the
in-flight matvec, so communication hides behind computation — and
pipelined recurrences only stay trustworthy if dtype discipline holds.
This package formalizes those invariants as named, composable **contract
passes** over traced jaxprs (with an HLO backend for post-compiler
re-proof) and is the single source of truth every probe site consumes:
the structural tests, the overlap benchmark, the session hook
(:meth:`repro.api.LinearSolver.verify_contracts`), and the CI audit.

    from repro.analysis import trace_binding, run_passes

    tb = trace_binding("p-bicgsafe", op, binding="batched",
                       substrate="pallas", guard=True)
    report = run_passes(tb)
    assert report.ok, report.violations

    # or sweep the whole binding matrix (what CI runs):
    #   python -m repro.analysis audit [--quick]

Layout:

* :mod:`jaxpr_tools` — the ONE jaxpr-walking toolbox (formerly
  triplicated across test/bench probe files).
* :mod:`trace`       — trace any session binding (single / batched /
  open-loop service chunk / mesh) into a ``TracedBinding``; tracing
  only, zero solver executions.
* :mod:`passes`      — the contract passes + registry:
  ``one_reduction_per_iteration``, ``overlap_edge_free``,
  ``single_psum_sharded``, ``kernel_backed``, ``dtype_flow``.
* :mod:`report`      — typed ``Finding`` / ``ContractReport`` with jaxpr
  provenance, plus the human-readable contract table.
* :mod:`hlo`         — the HLO text backend (absorbed
  ``repro.launch.hlo_analysis``): collective stats, ``HloGraph``,
  ``overlap_report``.
* :mod:`audit`       — the full binding-matrix sweep behind
  ``python -m repro.analysis audit``; emits
  ``experiments/contract_audit.json``.
"""
from .jaxpr_tools import (count_prim, eqn_needs_ppermute, find_prim_eqn,
                          find_prim_eqns, find_while_body, nonliteral,
                          subjaxprs, transitive_inputs)
from .passes import PASSES, contract_pass, reduction_consumes_matvec, \
    run_passes
from .report import (BindingSpec, ContractReport, Finding, format_table)
from .trace import (REDUCE_MARK_DIM, TracedBinding, tag_matvec, tag_reduce,
                    trace_binding, trace_fn)

__all__ = [
    # toolbox
    "subjaxprs", "find_while_body", "count_prim", "find_prim_eqn",
    "find_prim_eqns", "nonliteral", "transitive_inputs",
    "eqn_needs_ppermute",
    # tracing
    "TracedBinding", "trace_binding", "trace_fn", "tag_reduce",
    "tag_matvec", "REDUCE_MARK_DIM",
    # passes
    "PASSES", "contract_pass", "run_passes", "reduction_consumes_matvec",
    # reports
    "BindingSpec", "ContractReport", "Finding", "format_table",
]
