"""The ONE jaxpr-walking toolbox for the contract analyzer and probes.

Before :mod:`repro.analysis` existed, ``subjaxprs`` / ``find_while_body``
/ ``count_prim`` were triplicated across ``tests/_jaxpr_utils.py``,
``tests/_distributed_check.py`` and ``benchmarks/_overlap_child.py``,
and four test files re-derived the reverse transitive-dependency walk
inline.  Every walker lives here now; the jaxpr vocabulary types come
from :mod:`repro.core.compat` (``jax.extend.core`` with a
version-guarded fallback), so none of this emits DeprecationWarnings on
newer jax.

All walkers recurse through nested jaxprs (``pjit``, ``scan``,
``while``, custom-call bodies) — a probe must see through the session
layer's jit wrapping and the substrate's kernel dispatch.
"""
from __future__ import annotations

from typing import Iterable, Iterator, List, Optional, Set, Tuple

from repro.core.compat import Jaxpr, Literal


def subjaxprs(eqn) -> Iterator[Jaxpr]:
    """Yield every sub-jaxpr referenced by an equation's params."""
    for v in eqn.params.values():
        for sub in (v if isinstance(v, (list, tuple)) else [v]):
            j = getattr(sub, "jaxpr", sub)
            if isinstance(j, Jaxpr):
                yield j


def find_while_body(jaxpr: Jaxpr) -> Optional[Jaxpr]:
    """First while-loop body jaxpr, searching nested jaxprs depth-first."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            return eqn.params["body_jaxpr"].jaxpr
        for sub in subjaxprs(eqn):
            found = find_while_body(sub)
            if found is not None:
                return found
    return None


def count_prim(jaxpr: Jaxpr, name: str) -> int:
    """Occurrences of a primitive in a jaxpr, including nested jaxprs."""
    cnt = sum(1 for eqn in jaxpr.eqns if eqn.primitive.name == name)
    for eqn in jaxpr.eqns:
        for sub in subjaxprs(eqn):
            cnt += count_prim(sub, name)
    return cnt


def find_prim_eqn(jaxpr: Jaxpr, name: str):
    """First equation of the given primitive, searching nested jaxprs."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            return eqn
        for sub in subjaxprs(eqn):
            found = find_prim_eqn(sub, name)
            if found is not None:
                return found
    return None


def find_prim_eqns(jaxpr: Jaxpr, name: str) -> List:
    """ALL equations of the given primitive, including nested jaxprs."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            out.append(eqn)
        for sub in subjaxprs(eqn):
            out.extend(find_prim_eqns(sub, name))
    return out


def nonliteral(vs: Iterable) -> Set:
    """The variable (non-``Literal``) subset of an invar/outvar list."""
    return {v for v in vs if not isinstance(v, Literal)}


def transitive_inputs(body: Jaxpr, target_eqn) -> Set:
    """Every variable ``target_eqn`` transitively consumes within ``body``.

    One reverse pass over the body's equations, growing the needed set —
    the shared core of every overlap probe in the repo.  Equations are
    treated atomically (a needed pjit/scan output pulls in all of that
    equation's inputs), which is conservative: it can only ever report
    MORE dependencies, never hide a real edge.
    """
    needed = nonliteral(target_eqn.invars)
    for eqn in reversed(body.eqns):
        if eqn is target_eqn:
            continue
        if any(ov in needed for ov in eqn.outvars):
            needed |= nonliteral(eqn.invars)
    return needed


def eqn_consumes(body: Jaxpr, target_eqn, producer_outvars: Set) -> bool:
    """Does ``target_eqn`` transitively consume any of the given outputs?"""
    return bool(set(producer_outvars) & transitive_inputs(body, target_eqn))


def eqn_needs_ppermute(body: Jaxpr, target_eqn) -> Tuple[Set, bool]:
    """Overlap probe: does ``target_eqn`` (e.g. the psum of the fused dot
    partials) transitively consume any ppermute output of ``body``?

    Returns ``(permute_outs, needs)`` — the set of halo-exchange outputs
    found in the body, and whether the target depends on any of them
    (False == no dependency edge == the reduction may overlap the
    in-flight matvec).
    """
    permute_outs: Set = set()
    for eqn in body.eqns:
        if eqn.primitive.name == "ppermute":
            permute_outs.update(eqn.outvars)
    return permute_outs, eqn_consumes(body, target_eqn, permute_outs)
