"""Trace session bindings into analyzable jaxprs — zero solver executions.

The contract passes are *static*: they consume the jaxpr of a solver
program, never its outputs.  :func:`trace_binding` builds that jaxpr for
any cell of the scenario matrix (method x substrate x binding kind x
guard x precond x mesh) with two instrumentation tags, both implemented
with ``lax.optimization_barrier`` (semantically the identity, so the
traced program IS the production program's dataflow):

* every ``dot_reduce`` call is tagged together with a ``(13,)`` marker
  constant — a shape no solver's partial block can collide with (the
  widest fused phase is the guarded ``(11, m)``) — so reduction phases
  are identifiable in the while body regardless of the method's partial
  shapes;
* the operator's matvec output is tagged bare, so the overlap pass can
  ask whether a reduction transitively consumes the in-flight matvec.

Mesh bindings need no tags: there the reduction IS the ``psum``
primitive and the halo exchange IS ``ppermute``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import SOLVERS, SolverConfig
from repro.core._deprecation import internal_use
from repro.core.linear_operator import Stencil7Operator
from repro.core.multirhs import init_state, solve_batched, step_chunk
from repro.core.substrate import get_substrate

from .jaxpr_tools import find_while_body
from .report import BindingSpec

__all__ = ["TracedBinding", "trace_binding", "trace_fn",
           "REDUCE_MARK_DIM", "tag_reduce", "tag_matvec"]

#: marker length for reduction tags; no solver partial block has a
#: leading dim of 13 (max is the guarded 11), so the marker output
#: uniquely identifies reduce-tag equations in the while body.
REDUCE_MARK_DIM = 13


def tag_reduce(partials):
    """A ``dot_reduce`` that tags the fused partial block in the jaxpr."""
    mark = jnp.zeros((REDUCE_MARK_DIM,), partials.dtype)
    out, _ = lax.optimization_barrier((partials, mark))
    return out


def tag_matvec(mv: Callable) -> Callable:
    """Wrap a matvec so its output is barrier-tagged in the jaxpr."""
    return lambda x: lax.optimization_barrier(mv(x))


@dataclasses.dataclass
class TracedBinding:
    """One traced session binding: the analyzer's input unit."""

    spec: BindingSpec
    jaxpr: Any                       # the ClosedJaxpr of the whole program
    body: Any                        # the while-loop body jaxpr (or None)

    # -- tag accessors (local bindings) -----------------------------------

    def _barrier_eqns(self) -> List:
        if self.body is None:
            return []
        return [e for e in self.body.eqns
                if e.primitive.name == "optimization_barrier"]

    def reduce_eqns(self) -> List:
        """Reduction-phase equations in the while body: the marker-tagged
        barriers (local bindings) or the ``psum`` equations (mesh)."""
        if self.spec.binding == "mesh":
            from .jaxpr_tools import find_prim_eqns
            return [] if self.body is None \
                else find_prim_eqns(self.body, "psum")
        return [e for e in self._barrier_eqns()
                if len(e.outvars) >= 2
                and tuple(e.outvars[-1].aval.shape) == (REDUCE_MARK_DIM,)]

    def matvec_tag_eqns(self) -> List:
        """Matvec-output tags in the while body (local bindings only)."""
        return [e for e in self._barrier_eqns()
                if not (len(e.outvars) >= 2 and
                        tuple(e.outvars[-1].aval.shape)
                        == (REDUCE_MARK_DIM,))]


def _operator_matvec(operator) -> Callable:
    if hasattr(operator, "matvec"):
        return operator.matvec
    if callable(operator):
        return operator
    raise TypeError(
        f"cannot trace operator of type {type(operator).__name__}: "
        "need .matvec or a callable")


def _operator_dim(operator, n: Optional[int]) -> int:
    if n is not None:
        return int(n)
    if hasattr(operator, "shape"):
        return int(operator.shape[0])
    for attr in ("n",):
        if hasattr(operator, attr):
            return int(getattr(operator, attr))
    raise ValueError(
        "cannot infer the operator dimension for tracing; pass n= "
        "(bare-callable operators carry no shape)")


def _float_dtype():
    import numpy as np
    return jax.dtypes.canonicalize_dtype(np.float64)   # f64 under x64


def _precond_kernel_count(pc, sub) -> int:
    """Pallas kernels the bound preconditioner is expected to add to the
    iteration body.  Only block-Jacobi has a dedicated apply kernel, and
    only when its blocks actually vary (nb > 1): the shared-block case is
    one dense matmul the kernel layer deliberately routes to the
    reference path (XLA maps it onto the MXU already) — policy, not a
    silent fallback."""
    if pc is None or not getattr(sub, "kernel_backed", False):
        return 0
    from repro.precond.block_jacobi import BlockJacobiPreconditioner
    if isinstance(pc, BlockJacobiPreconditioner) \
            and pc.inv_blocks.shape[0] > 1:
        return 1
    return 0


def _resolve_precond_instance(precond, operator):
    """Build a name-spec preconditioner against the REAL operator (the
    probe hands the solver a tagged matvec closure, which a name spec
    could not build from); instances pass through."""
    if precond is None or not isinstance(precond, str):
        return precond
    from repro.precond.base import resolve_precond
    return resolve_precond(precond, operator)


def trace_fn(fn: Callable, *args, spec: BindingSpec) -> TracedBinding:
    """Trace an arbitrary probe function into a :class:`TracedBinding`.

    The low-level entry the pass-level unit tests use to hand-build
    violating programs; :func:`trace_binding` routes everything through
    it too.
    """
    with internal_use():
        closed = jax.make_jaxpr(fn)(*args)
    return TracedBinding(spec=spec, jaxpr=closed,
                         body=find_while_body(closed.jaxpr))


def trace_binding(method: str,
                  operator,
                  *,
                  binding: str = "single",
                  substrate: str = "jnp",
                  precond=None,
                  guard: bool = False,
                  m: int = 3,
                  n: Optional[int] = None,
                  config: Optional[SolverConfig] = None,
                  mesh=None,
                  blocked: bool = False) -> TracedBinding:
    """Trace one scenario-matrix cell.  Tracing only — no solve runs.

    Args:
      method: a name from :data:`repro.core.SOLVERS`.
      operator: operator object (preferred; preconditioner name specs
        and mesh bindings need one) or a bare matvec callable (with
        ``n=``).
      binding: ``"single"`` | ``"batched"`` | ``"open_loop"`` (the
        service-chunk program) | ``"mesh"`` (the sharded batched driver;
        requires a :class:`Stencil7Operator` and ``mesh=``).
      guard: trace with ``SolverConfig.guard`` — the (11, m) fused
        phase on the bindings that support it (recorded as
        ``spec.guard_effective``).
      precond: ``None`` | name | Preconditioner instance.
      m: column count for batched/open-loop/mesh bindings.
      blocked: ``operator`` is already an (n, m) -> (n, m) block matvec.
    """
    if method not in SOLVERS:
        raise ValueError(f"unknown method {method!r}")
    if binding not in ("single", "batched", "open_loop", "mesh"):
        raise ValueError(f"unknown binding kind {binding!r}")
    sub = get_substrate(substrate)
    cfg = config if config is not None else SolverConfig(maxiter=8)
    if guard != cfg.guard:
        cfg = dataclasses.replace(cfg, guard=guard)
    precond_name = precond if isinstance(precond, str) else (
        getattr(precond, "name", None) if precond is not None else None)
    guard_effective = bool(guard) and binding in ("batched", "open_loop",
                                                  "mesh")
    dtype = _float_dtype()

    if binding == "mesh":
        if mesh is None:
            raise ValueError("binding='mesh' requires mesh=")
        if not isinstance(operator, Stencil7Operator):
            raise TypeError("binding='mesh' requires a Stencil7Operator")
        from repro.core.distributed import (build_stencil_solver,
                                            build_stencil_solver_batched)
        spec = BindingSpec(method=method, substrate=sub.name, binding="mesh",
                           guard=guard, precond=precond_name, m=m,
                           mesh_shape=tuple(mesh.devices.shape),
                           guard_effective=guard_effective)
        op = operator
        if method == "p-bicgsafe":
            B_grid = jnp.ones((op.nx, op.ny, op.nz, m), dtype)
            with internal_use():
                fn = build_stencil_solver_batched(
                    op, mesh, config=cfg, substrate=sub.name,
                    precond=precond, jit=False)
            return trace_fn(fn, B_grid, spec=spec)
        b_grid = jnp.ones((op.nx, op.ny, op.nz), dtype)
        with internal_use():
            fn = build_stencil_solver(SOLVERS[method], op, mesh, config=cfg,
                                      substrate=sub.name, precond=precond,
                                      jit=False)
        return trace_fn(fn, b_grid, spec=dataclasses.replace(spec, m=1))

    pc = _resolve_precond_instance(precond, operator)
    dim = _operator_dim(operator, n)
    precond_kernels = _precond_kernel_count(pc, sub)

    if binding == "single":
        if blocked:
            raise ValueError("binding='single' cannot trace a block matvec")
        mv = tag_matvec(_operator_matvec(operator))
        b = jnp.ones((dim,), dtype)
        spec = BindingSpec(method=method, substrate=sub.name,
                           binding="single", guard=guard,
                           precond=precond_name, m=1,
                           guard_effective=False,
                           precond_kernels=precond_kernels)

        def run(bb):
            return SOLVERS[method](mv, bb, config=cfg,
                                   dot_reduce=tag_reduce, substrate=sub,
                                   precond=pc)
        return trace_fn(run, b, spec=spec)

    # batched / open_loop: the p-BiCGSafe block iteration only
    if method != "p-bicgsafe":
        raise ValueError(
            f"binding={binding!r} runs the batched p-BiCGSafe iteration "
            f"only (got method={method!r})")
    if blocked:
        bmv = tag_matvec(operator)
    else:
        bmv = tag_matvec(sub.as_block_matvec(operator))
    B = jnp.ones((dim, m), dtype)
    spec = BindingSpec(method=method, substrate=sub.name, binding=binding,
                       guard=guard, precond=precond_name, m=m,
                       guard_effective=guard_effective,
                       precond_kernels=precond_kernels)

    if binding == "batched":
        def run(BB):
            return solve_batched(bmv, BB, config=cfg, dot_reduce=tag_reduce,
                                 substrate=sub, blocked=True, precond=pc)
        return trace_fn(run, B, spec=spec)

    # open_loop: the service-chunk program — init fused into the chunk so
    # tracing never executes a matvec eagerly
    papply = None if pc is None else sub.as_precond_apply(pc)

    def run(BB):
        BB = BB if papply is None else papply(BB)
        st = init_state(bmv, BB, config=cfg, dot_reduce=tag_reduce,
                        substrate=sub)
        return step_chunk(bmv, st, cfg.maxiter, config=cfg,
                          dot_reduce=tag_reduce, substrate=sub)
    return trace_fn(run, B, spec=spec)
