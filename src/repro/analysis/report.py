"""Typed findings and reports for the contract analyzer.

A :class:`ContractReport` is the unit the analyzer emits: one traced
session binding (method x substrate x binding kind x guard x precond x
mesh), with one :class:`Finding` per contract pass that ran.  A finding
carries jaxpr provenance — which equation(s) the pass anchored its
verdict on — so a violation points at the offending primitive, not just
at a boolean.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

#: finding statuses
OK = "ok"
VIOLATION = "violation"
SKIPPED = "skipped"


def eqn_provenance(eqn, limit: int = 120) -> str:
    """One-line provenance for a jaxpr equation: primitive + shapes."""
    try:
        outs = ", ".join(str(getattr(v, "aval", v)) for v in eqn.outvars)
        s = f"{eqn.primitive.name} -> {outs}"
    except Exception:                      # pragma: no cover - defensive
        s = str(eqn.primitive)
    return s if len(s) <= limit else s[:limit - 3] + "..."


@dataclasses.dataclass(frozen=True)
class Finding:
    """Outcome of ONE contract pass over ONE traced binding."""

    contract: str
    status: str                       # "ok" | "violation" | "skipped"
    detail: str = ""
    provenance: Tuple[str, ...] = ()

    @property
    def ok(self) -> bool:
        return self.status != VIOLATION

    def to_dict(self) -> Dict:
        return {"contract": self.contract, "status": self.status,
                "detail": self.detail, "provenance": list(self.provenance)}


@dataclasses.dataclass(frozen=True)
class BindingSpec:
    """What was traced: the coordinates of one cell of the scenario
    matrix.  ``guard_effective`` records whether ``guard=True`` actually
    widens the fused phase on this binding (only the batched/open-loop/
    mesh p-BiCGSafe paths carry health rows; single-RHS solvers ignore
    the flag) — passes key their (9 vs 11) expectations on it."""

    method: str
    substrate: str
    binding: str                      # single | batched | open_loop | mesh
    guard: bool = False
    precond: Optional[str] = None
    m: int = 1
    mesh_shape: Optional[Tuple[int, ...]] = None
    guard_effective: bool = False
    #: extra pallas kernels the bound preconditioner is expected to add
    #: to the iteration body (set at trace time from the RESOLVED
    #: instance: block-Jacobi's apply kernel only engages when nb > 1 —
    #: the shared-block nb == 1 case legitimately short-circuits to one
    #: dense matmul, not a silent fallback)
    precond_kernels: int = 0

    @property
    def label(self) -> str:
        bits = [self.method, self.substrate, self.binding]
        if self.guard:
            bits.append("guard")
        if self.precond:
            bits.append(str(self.precond))
        if self.mesh_shape:
            bits.append("mesh" + "x".join(map(str, self.mesh_shape)))
        return "/".join(bits)

    def to_dict(self) -> Dict:
        return {"method": self.method, "substrate": self.substrate,
                "binding": self.binding, "guard": self.guard,
                "precond": self.precond, "m": self.m,
                "mesh_shape": (None if self.mesh_shape is None
                               else list(self.mesh_shape)),
                "guard_effective": self.guard_effective,
                "precond_kernels": self.precond_kernels}


@dataclasses.dataclass(frozen=True)
class ContractReport:
    """All contract findings for one traced binding."""

    spec: BindingSpec
    findings: Tuple[Finding, ...]

    @property
    def ok(self) -> bool:
        return all(f.ok for f in self.findings)

    @property
    def violations(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.status == VIOLATION)

    def finding(self, contract: str) -> Optional[Finding]:
        for f in self.findings:
            if f.contract == contract:
                return f
        return None

    def to_dict(self) -> Dict:
        return {"binding": self.spec.to_dict(),
                "ok": self.ok,
                "findings": [f.to_dict() for f in self.findings]}


_STATUS_CELL = {OK: "pass", VIOLATION: "FAIL", SKIPPED: "-"}


def format_table(reports: Sequence[ContractReport],
                 contracts: Optional[Sequence[str]] = None) -> str:
    """Human-readable contract table: one row per binding, one column
    per contract pass (``pass`` / ``FAIL`` / ``-`` for not-applicable)."""
    if contracts is None:
        seen: List[str] = []
        for r in reports:
            for f in r.findings:
                if f.contract not in seen:
                    seen.append(f.contract)
        contracts = seen
    headers = ["binding"] + list(contracts)
    rows = []
    for r in reports:
        row = [r.spec.label]
        for c in contracts:
            f = r.finding(c)
            row.append(_STATUS_CELL.get(f.status, "?") if f else "-")
        rows.append(row)
    widths = [max(len(h), *(len(row[i]) for row in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    lines = [fmt.format(*headers), fmt.format(*("-" * w for w in widths))]
    lines += [fmt.format(*row) for row in rows]
    return "\n".join(lines)
