"""HLO backend of the contract analyzer: text analysis of lowered modules.

The jaxpr passes (:mod:`repro.analysis.passes`) prove the contracts on
the traced program; this module re-proves the overlap contract AFTER the
XLA compiler has had its say, on compiled HLO text — the two layers
together are the full static story.  (Formerly
``repro.launch.hlo_analysis``; that import path remains as a shim.)

``collective_stats``   sums operand/result sizes of every collective in an
HLO module text and estimates wire bytes per device (ring-algorithm
conventions).  This feeds the roofline's collective term — cost_analysis()
does not report collectives.

``HloGraph``           a small parser of HLO text into an op graph, used by
``overlap_report`` to prove structurally that p-BiCGSafe's fused
all-reduce has no dependency path to/from the overlapped SpMV while
ssBiCGSafe2's does.

``overlap_report``     the pass-level consumer benchmarks/bench_overlap.py
(via its 8-fake-device child) runs on compiled distributed solver text.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f16|c64|c128)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[\w\[\],{}\s]*?))\s*"
    r"([\w\-]+)\(([^)]*)\)(.*)$")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]
    wire_bytes: Dict[str, float]     # est. bytes on the wire per device
    wire_by_dtype: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())

    def tpu_wire_bytes(self, bf16_program: bool) -> float:
        """XLA:CPU legalizes bf16 to f32; for bf16 programs the f32
        collective bytes are 2x what a TPU build moves."""
        if not bf16_program:
            return self.total_wire_bytes
        f32 = self.wire_by_dtype.get("f32", 0.0)
        return self.total_wire_bytes - f32 / 2


def collective_stats(hlo_text: str, n_devices: int = 1,
                     while_body_multiplier: float = 1.0) -> CollectiveStats:
    """Sum collective sizes over the module.

    ``while_body_multiplier``: collectives inside while-loop bodies execute
    once per trip, but HLO text lists them once; pass the scan length
    (n_layers for the layer scan) to correct the totals.  Applied to every
    while body (the layer scan is the only collective-bearing loop in the
    step functions).
    """
    if while_body_multiplier != 1.0:
        comps = split_computations(hlo_text)
        bodies = set()
        for line in hlo_text.splitlines():
            m = re.search(r"\bwhile\(.*?body=%?([\w.\-]+)", line)
            if m:
                bodies.add(m.group(1))
        total = CollectiveStats({}, {}, {})
        for name, body in comps.items():
            sub = collective_stats(body, n_devices, 1.0)
            k = while_body_multiplier if name in bodies else 1.0
            for c in sub.counts:
                total.counts[c] = total.counts.get(c, 0) \
                    + int(sub.counts[c] * k)
                total.result_bytes[c] = total.result_bytes.get(c, 0) \
                    + int(sub.result_bytes[c] * k)
                total.wire_bytes[c] = total.wire_bytes.get(c, 0.0) \
                    + sub.wire_bytes[c] * k
            for dt, b in sub.wire_by_dtype.items():
                total.wire_by_dtype[dt] = total.wire_by_dtype.get(dt, 0.0) \
                    + b * k
        return total

    counts: Dict[str, int] = defaultdict(int)
    rbytes: Dict[str, int] = defaultdict(int)
    wire: Dict[str, float] = defaultdict(float)
    wire_dt: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        m = re.search(r"=\s*((?:\([^)]*\))|(?:[^\s]+))\s+([\w\-]+)", s)
        if not m:
            continue
        typestr, opname = m.group(1), m.group(2)
        base = opname.split(".")[0]
        # normalize fused/async variants: all-reduce-start, all-gather-done...
        for c in COLLECTIVES:
            if base == c or base == c + "-start":
                if base.endswith("-start") and "-done" in s:
                    continue
                sz = _shape_bytes(typestr)
                g = _group_size(s, n_devices)
                counts[c] += 1
                rbytes[c] += sz
                if c == "all-reduce":
                    w = 2.0 * sz * (g - 1) / max(g, 1)
                elif c in ("all-gather", "all-to-all"):
                    w = sz * (g - 1) / max(g, 1)
                elif c == "reduce-scatter":
                    # result is the scattered shard; wire ~ result*(g-1)
                    w = sz * (g - 1)
                else:  # collective-permute
                    w = sz
                wire[c] += w
                dts = _SHAPE_RE.findall(typestr)
                if dts:
                    wire_dt[dts[0][0]] += w
                break
    return CollectiveStats(dict(counts), dict(rbytes), dict(wire),
                           dict(wire_dt))


# ---------------------------------------------------------------------------
# dependency graph
# ---------------------------------------------------------------------------

def split_computations(hlo_text: str) -> Dict[str, str]:
    """Split an HLO module's text into {computation_name: body_text}.

    A computation header is any non-instruction line ending with '{'
    (parameter tuples may contain nested parens, so we only parse the
    leading name token).
    """
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and "=" not in s.split("(", 1)[0]:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", s)
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if s == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


class HloGraph:
    """Def-use graph over one HLO computation (by instruction name)."""

    def __init__(self, computation_text: str):
        self.ops: Dict[str, str] = {}       # name -> opcode
        self.deps: Dict[str, List[str]] = {}  # name -> operand names
        for line in computation_text.splitlines():
            s = line.strip()
            if "=" not in s:
                continue
            m = re.match(
                r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                r"(?:\([^=]*?\)|[\w\[\],{}]+)\s+([\w\-]+)\(", s)
            if not m:
                continue
            name, opcode = m.group(1), m.group(2)
            rest = s[m.end():]
            args = re.findall(r"%([\w.\-]+)", rest)
            # strip attribute references like to_apply=%add
            self.ops[name] = opcode
            self.deps[name] = [a for a in args if a != name]

    def find(self, opcode_prefix: str) -> List[str]:
        return [n for n, op in self.ops.items()
                if op.startswith(opcode_prefix)]

    def ancestors(self, name: str) -> Set[str]:
        seen: Set[str] = set()
        stack = [name]
        while stack:
            n = stack.pop()
            for d in self.deps.get(n, []):
                if d not in seen and d in self.ops:
                    seen.add(d)
                    stack.append(d)
        return seen

    def depends_on(self, a: str, b: str) -> bool:
        """True if op a transitively consumes op b."""
        return b in self.ancestors(a)

    def independent(self, a: str, b: str) -> bool:
        return not self.depends_on(a, b) and not self.depends_on(b, a)


# ---------------------------------------------------------------------------
# the overlap contract on compiled HLO
# ---------------------------------------------------------------------------

def _result_dims(body_text: str, opname: str) -> str:
    for line in body_text.splitlines():
        s = line.strip()
        if s.startswith(f"%{opname} =") or s.startswith(f"{opname} =") or \
                s.startswith(f"ROOT %{opname} =") or \
                s.startswith(f"ROOT {opname} ="):
            return s.split("=", 1)[1][:80]
    return ""


def overlap_report(hlo_text: str, reduce_dim: str = "9") -> dict:
    """Overlap contract over compiled HLO: dependency structure between
    the fused-dots all-reduce and the halo collective-permutes.

    Finds the computation holding both an ``all-reduce`` whose result
    mentions ``reduce_dim`` (the stacked partial block: "9" covers
    ``(9,)`` and ``(9, m)``; pass "11" for guarded programs) and
    collective-permutes, then counts dependency edges each way.  The
    contract holds when ``reduction_needs_permutes == 0`` with
    ``independent_of_reduction > 0`` — the scheduler MAY overlap; it is
    structurally violated (ssBiCGSafe2) when the reduction consumes the
    permutes.
    """
    comps = split_computations(hlo_text)
    best = None
    for name, body in comps.items():
        g = HloGraph(body)
        ars = [n for n in g.find("all-reduce")
               if reduce_dim in _result_dims(body, n)]
        cps = g.find("collective-permute")
        if ars and cps:
            best = (name, g, ars, cps)
            break
    if best is None:
        return {"error": f"no body with all-reduce({reduce_dim}) + "
                "collective-permute"}
    name, g, ars, cps = best
    ar = ars[0]
    return {
        "computation": name,
        "n_halo_permutes": len(cps),
        "independent_of_reduction": len([cp for cp in cps
                                         if g.independent(ar, cp)]),
        "permutes_needing_reduction": len([cp for cp in cps
                                           if g.depends_on(cp, ar)]),
        "reduction_needs_permutes": len([cp for cp in cps
                                         if g.depends_on(ar, cp)]),
    }
