"""Serving example: batched requests through the continuous-batching
engine (prefill + slot decode), greedy decoding.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b
"""
import argparse
import time

import numpy as np

from repro.configs import smoke_config
from repro.serve import Request, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    eng = ServingEngine(cfg, ServeConfig(max_batch=4, max_len=128))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.choice([8, 8, 16]))
        eng.submit(Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                                    plen).astype(int)),
                           max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    for r in done:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"{r.output[:6]}... ({len(r.output)} tokens)")
    print(f"{len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
