"""End-to-end training driver: a ~100M-parameter LM with the full
substrate — data pipeline, AdamW + pipelined clipping, bad-step gating,
atomic checkpoints, restart recovery.

Presets:
  --preset 10m    ~10M params, 300 steps  (default; minutes on CPU)
  --preset 100m   ~114M params            (the deliverable config; pass
                  --steps to taste — ~1 min/step on this CPU)

  PYTHONPATH=src python examples/train_lm.py --preset 10m --steps 300
"""
import argparse
import time

from repro.data import DataConfig
from repro.models import ModelConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab) ~ param count
    "1m": (2, 128, 4, 2, 512, 2048),
    "10m": (4, 384, 6, 2, 1536, 8192),       # ~14M
    "100m": (12, 768, 12, 4, 3072, 32064),   # ~114M
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    L, d, h, kv, ff, V = PRESETS[args.preset]
    cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                      n_layers=L, d_model=d, n_heads=h, n_kv_heads=kv,
                      d_ff=ff, vocab_size=V, remat="none")
    n_params = (V * d * 2 + L * (4 * d * d // (h // kv if kv else 1)
                                 + 3 * d * ff))
    print(f"config {cfg.name}: ~{n_params/1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.batch_size}x{args.seq_len}")

    dcfg = DataConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                      vocab_size=V)
    tcfg = TrainConfig(
        steps=args.steps, ckpt_every=max(50, args.steps // 4),
        ckpt_dir=args.ckpt_dir,
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                        decay_steps=args.steps))

    t0 = time.time()
    losses = []

    def cb(step, rec):
        losses.append(rec["loss"])
        if step % 5 == 0:
            print(f"  step {step:4d} loss {rec['loss']:.4f} "
                  f"gnorm {rec['grad_norm']:.2f} "
                  f"({rec['time_s']*1e3:.0f} ms/step)", flush=True)

    out = train(cfg, dcfg, tcfg, callback=cb)
    dt = time.time() - t0
    if not losses:
        print(f"nothing to do: resumed at step {out['start_step']} "
              f">= {args.steps} (checkpoint complete)")
        return
    print(f"\ndone: steps {out['start_step']}..{args.steps}, "
          f"loss {losses[0]:.4f} -> {out['final_loss']:.4f} "
          f"in {dt:.0f}s; rejected={out['rejected_steps']}, "
          f"stragglers={out['straggler_stats']}")


if __name__ == "__main__":
    main()
