"""End-to-end distributed solve (the paper's own workload).

Solves a 1.1M-row convection-diffusion system with all solvers on an
8-device (data, model) mesh — the same shard_map + halo-exchange + single
fused psum runtime that the 512-chip dry-run exercises.

  PYTHONPATH=src python examples/distributed_solve.py [--n 104]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

import repro  # noqa: E402
from repro.core import SolverConfig  # noqa: E402
from repro.core import matrices as M  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=104,
                    help="grid size (n^3 unknowns; must be divisible by 8)")
    args = ap.parse_args()
    n = args.n
    op, b, xt = M.convection_diffusion(n, peclet=1.0)
    print(f"convection-diffusion, {n}^3 = {n**3:,} unknowns, "
          f"{jax.device_count()} devices, mesh (4, 2) = (data, model)")
    from repro.core.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    b_grid = b.reshape(n, n, n)
    for name in ("p-bicgsafe", "ssbicgsafe2", "bicgstab", "p-bicgstab"):
        t0 = time.perf_counter()
        # bind-once front door: the mesh-bound session builds the
        # shard_map program once; repeat solves would reuse it
        dist = repro.make_solver(name, op,
                                 config=SolverConfig(tol=1e-8)).on_mesh(mesh)
        res = dist.solve(b_grid)
        jax.block_until_ready(res.x)
        dt = time.perf_counter() - t0
        err = float(jnp.linalg.norm(res.x.reshape(-1) - xt)
                    / jnp.linalg.norm(xt))
        print(f"  {name:12s} iters={int(res.iterations):4d} "
              f"conv={bool(res.converged)} err={err:.1e} "
              f"wall={dt:.2f}s (incl. compile)")


if __name__ == "__main__":
    main()
