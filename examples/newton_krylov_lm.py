"""The paper's solver as the training optimizer: truncated Gauss-Newton
steps with p-BiCGSafe as the inner Krylov solver (DESIGN.md §4).

  PYTHONPATH=src python examples/newton_krylov_lm.py
"""
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import forward, init_params, loss_fn
from repro.optim.newton_krylov import NewtonKrylovConfig, newton_krylov_step


def main():
    cfg = smoke_config("phi3-mini-3.8b").replace(
        n_layers=2, dtype=jnp.float32, param_dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size)}

    def logits_fn(p, b):
        return forward(p, cfg, b)[0]

    def lossf(p, b):
        return loss_fn(p, cfg, b)[0]

    nk = NewtonKrylovConfig(damping=1e-2, inner_maxiter=12, inner_tol=1e-2,
                            trust_radius=5.0)
    print(f"Newton-Krylov (inner solver: p-BiCGSafe) on {cfg.name} smoke")
    loss = float(lossf(params, batch))
    print(f"  step 0: loss {loss:.4f}")
    for step in range(1, 6):
        params, m = newton_krylov_step(lossf, logits_fn, params, batch, nk)
        print(f"  step {step}: loss {float(m['new_loss']):.4f} "
              f"(inner iters {int(m['inner_iters'])}, "
              f"relres {float(m['inner_relres']):.1e}, "
              f"step scale {float(m['step_scale'])})")


if __name__ == "__main__":
    main()
