"""Quickstart: the paper's solver in 30 lines + a tiny LM train step.

  PYTHONPATH=src python examples/quickstart.py

Choosing a substrate
--------------------
Every solver takes ``substrate="jnp"`` (default) or ``substrate="pallas"``
(:mod:`repro.core.substrate`), selecting who computes the hot-loop phases:

* ``"jnp"`` issues the 9 inner products of the fused phase as 9 separate
  reductions (18 operand streams from HBM) and the Alg. 3.1 update phase
  as ~10 individual AXPYs — simple, and fine when the solve is small or
  the matvec dominates.
* ``"pallas"`` runs the hand-tiled kernels: the 9-dot phase reads each of
  its 5 vectors from HBM exactly once, and the whole vector-update phase
  is one pass (12 tile reads + 10 writes instead of ~30 reads + 10
  writes).  Both phases are memory-bound (arith intensity ~0.6 flop/byte,
  see kernels/fused_axpy.py), so at the ~819 GB/s HBM roofline the fused
  update phase is worth ~2.5x of the solver's vector-update time — the
  Pallas substrate wins whenever n is large enough that the solve is
  HBM-bound, i.e. exactly the paper's regime.  On TPU these are compiled
  Mosaic kernels; on CPU/GPU the same kernel bodies run in (slow)
  interpret mode — use "pallas" off-TPU only to validate numerics, not
  for speed.

Multi-RHS batching shifts the trade further: ``solve_batched`` streams
``(n, m)`` blocks, so each HBM pass and the single ``(9, m)`` reduction
are amortized over m right-hand sides — reduction latency per system
drops ~m-fold (the Krasnopolsky multi-RHS regime; see
benchmarks/bench_multirhs.py).

Every scenario x substrate combination runs the same kernel bodies:

* ``solve_batched(..., substrate="pallas")`` runs the whole hot loop on
  the (n, m) block kernels — ``fused_dots_batched`` (one (9, m) partial
  block per HBM pass), ``fused_axpy_batched`` (the 10-update phase with
  the per-column convergence mask applied in-kernel, so finished columns
  freeze without a second masking pass), and the block-ELL SpMV for
  banded ``ELLOperator``s (matrix tiles read once for all m columns).
* ``distributed_stencil_solve_batched(op, B_grid, mesh)`` shards the
  (n, m) block by rows over any mesh (``repro.launch.mesh`` —
  ``make_multirhs_mesh()`` gives the flat row ring) while columns stay
  local: per iteration there is still exactly ONE psum — now carrying the
  (9, m) block — and it keeps no dependency edge to the in-flight block
  matvec, so the paper's communication hiding survives batching+sharding
  (proven structurally in benchmarks/bench_overlap.py).

Preconditioning
---------------
Every solver (and both batched/distributed drivers) also takes
``precond=`` — ``"jacobi"``, ``"block_jacobi"``, ``"neumann"``, ``"ssor"``
or a :class:`repro.precond.Preconditioner` instance — and solves the
left-preconditioned system M^{-1} A x = M^{-1} b.  Which preconditioners
are substrate-kernel-backed and which are shard-local:

* ``block_jacobi`` — Pallas batched block-apply kernel on
  ``substrate="pallas"`` (shared-block stencil case: one MXU matmul);
  *exactly* shard-local in the distributed driver (z-line blocks never
  cross x-slab shards).
* ``neumann``      — rides the substrate's SpMV kernels (banded ELL ->
  Pallas block-ELL); shard-local additive-Schwarz flavor when
  distributed.
* ``jacobi``       — elementwise (XLA-fused, no kernel needed); exactly
  shard-local.
* ``ssor``         — stencil shifts (jnp body on either substrate);
  shard-local additive-Schwarz flavor when distributed.

The M^{-1}-applies are scheduled inside the pipelined solvers' overlap
window: one reduction per iteration, no dependency edge to the in-flight
precond+matvec, on every path (see repro/core/_common.py for the full
support matrix, and repro/precond for the subsystem).
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import (SolverConfig, bicgstab_solve, pbicgsafe_solve,  # noqa: E402
                        solve_batched, ssbicgsafe2_solve)
from repro.core import matrices as M  # noqa: E402


def solver_demo():
    print("== p-BiCGSafe vs baselines on a convection-diffusion system ==")
    op, b, x_true = M.convection_diffusion(24, peclet=1.0)  # 13824 rows
    for name, solve in (("BiCGStab", bicgstab_solve),
                        ("ssBiCGSafe2", ssbicgsafe2_solve),
                        ("p-BiCGSafe", pbicgsafe_solve)):
        res = solve(op.matvec, b, config=SolverConfig(tol=1e-8))
        err = float(jnp.linalg.norm(res.x - x_true)
                    / jnp.linalg.norm(x_true))
        print(f"  {name:12s} iterations={int(res.iterations):4d} "
              f"relres={float(res.relres):.2e} x_err={err:.2e}")


def precond_demo():
    print("\n== preconditioned p-BiCGSafe (repro.precond) ==")
    from repro.precond import block_jacobi
    # hard_nonsym: badly row-scaled — plain p-BiCGSafe stagnates, the
    # preconditioned solve converges in a few dozen iterations with the
    # M^{-1}-apply hidden inside the overlap window.
    op, b, x_true = M.hard_nonsym(n=600)
    cfg = SolverConfig(tol=1e-8, maxiter=3000)
    plain = pbicgsafe_solve(op, b, config=cfg)
    prec = pbicgsafe_solve(op, b, config=cfg, precond=block_jacobi(op),
                           substrate="pallas")
    err = float(jnp.linalg.norm(prec.x - x_true) / jnp.linalg.norm(x_true))
    print(f"  unpreconditioned: converged={bool(plain.converged)} "
          f"iterations={int(plain.iterations)}")
    print(f"  block-Jacobi (pallas apply): converged={bool(prec.converged)} "
          f"iterations={int(prec.iterations)} x_err={err:.2e}")
    # SSOR on the stencil family: same entry point, name spec
    op, b, _ = M.anisotropic3d(10, eps=1e-2)
    plain = pbicgsafe_solve(op, b, config=cfg)
    prec = pbicgsafe_solve(op, b, config=cfg, precond="ssor")
    print(f"  anisotropic3d: {int(plain.iterations)} iters -> "
          f"{int(prec.iterations)} with precond='ssor'")


def multirhs_demo():
    print("\n== batched multi-RHS p-BiCGSafe (one (9, m) reduction/iter) ==")
    op, b, _ = M.poisson3d(10)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    B = jnp.stack([b] + [jax.random.normal(k, b.shape, b.dtype)
                         for k in keys], axis=1)         # (n, 4)
    res = solve_batched(op.matvec, B, config=SolverConfig(tol=1e-8))
    for j in range(B.shape[1]):
        print(f"  rhs {j}: iterations={int(res.iterations[j]):4d} "
              f"relres={float(res.relres[j]):.2e} "
              f"converged={bool(res.converged[j])}")
    # same solve on the hand-tiled (n, m) block kernels (compiled on TPU,
    # interpret mode elsewhere) — same trajectory column by column; the
    # stopping iteration may flip by one where relres hovers at tol (the
    # kernel accumulates block-wise, jnp pairwise)
    res_k = solve_batched(op.matvec, B, config=SolverConfig(tol=1e-8),
                          substrate="pallas")
    same = [abs(int(res_k.iterations[j]) - int(res.iterations[j])) <= 1
            for j in range(B.shape[1])]
    print(f"  substrate='pallas' block kernels: converged="
          f"{bool(res_k.converged.all())}, per-column iteration "
          f"counts within +-1 of jnp: {all(same)}")


def lm_demo():
    print("\n== 5 training steps of a reduced qwen3 config ==")
    from repro.configs import smoke_config
    from repro.data import DataConfig
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, train

    cfg = smoke_config("qwen3-8b")
    out = train(cfg,
                DataConfig(batch_size=2, seq_len=32,
                           vocab_size=cfg.vocab_size),
                TrainConfig(steps=5, ckpt_every=100,
                            ckpt_dir="/tmp/repro-quickstart",
                            opt=AdamWConfig(lr=1e-3)))
    for h in out["history"]:
        print(f"  step {h['step']}: loss {h['loss']:.4f}")


if __name__ == "__main__":
    solver_demo()
    precond_demo()
    multirhs_demo()
    lm_demo()
