"""Quickstart: the paper's solver in 30 lines + a tiny LM train step.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import (SolverConfig, bicgstab_solve, pbicgsafe_solve,  # noqa: E402
                        ssbicgsafe2_solve)
from repro.core import matrices as M  # noqa: E402


def solver_demo():
    print("== p-BiCGSafe vs baselines on a convection-diffusion system ==")
    op, b, x_true = M.convection_diffusion(24, peclet=1.0)  # 13824 rows
    for name, solve in (("BiCGStab", bicgstab_solve),
                        ("ssBiCGSafe2", ssbicgsafe2_solve),
                        ("p-BiCGSafe", pbicgsafe_solve)):
        res = solve(op.matvec, b, config=SolverConfig(tol=1e-8))
        err = float(jnp.linalg.norm(res.x - x_true)
                    / jnp.linalg.norm(x_true))
        print(f"  {name:12s} iterations={int(res.iterations):4d} "
              f"relres={float(res.relres):.2e} x_err={err:.2e}")


def lm_demo():
    print("\n== 5 training steps of a reduced qwen3 config ==")
    from repro.configs import smoke_config
    from repro.data import DataConfig
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, train

    cfg = smoke_config("qwen3-8b")
    out = train(cfg,
                DataConfig(batch_size=2, seq_len=32,
                           vocab_size=cfg.vocab_size),
                TrainConfig(steps=5, ckpt_every=100,
                            ckpt_dir="/tmp/repro-quickstart",
                            opt=AdamWConfig(lr=1e-3)))
    for h in out["history"]:
        print(f"  step {h['step']}: loss {h['loss']:.4f}")


if __name__ == "__main__":
    solver_demo()
    lm_demo()
