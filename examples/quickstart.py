"""Quickstart: the paper's solver behind one front door + a tiny LM step.

  PYTHONPATH=src python examples/quickstart.py

The front door (``repro.api``)
------------------------------
Bind the operator ONCE, solve many times:

    solver = repro.make_solver("p-bicgsafe", op, precond="block_jacobi",
                               substrate="pallas")
    res = solver.solve(b)                 # traces + compiles once
    res = solver.solve(b2)                # replays the compiled program
    res = solver.solve_many([b3, b4])     # ONE (9, m) reduction/iter
    dist = solver.on_mesh(mesh)           # sharded, same session

or one-shot: ``repro.solve(op, b)`` (which still hits the content-keyed
session cache, so a second call against an equal-content operator reuses
the compiled program and the built preconditioner).

Everything is set at bind time and never re-threaded per call:

* ``method``  — any of ``repro.SOLVERS``: "bicgstab", "p-bicgstab",
  "gpbicg", "cgs", "ssbicgsafe2", "p-bicgsafe" (the paper's Alg. 3.1),
  "p-bicgsafe-rr" (Alg. 4.1).
* ``substrate`` — ``"jnp"`` (reference; 9 separate reductions for the
  fused phase) or ``"pallas"`` (hand-tiled kernels: one HBM pass for
  the 9-dot phase, one for the whole vector-update phase, block-ELL
  SpMV; compiled Mosaic on TPU, interpret mode elsewhere — use off-TPU
  to validate numerics, not for speed).
* ``precond`` — ``"jacobi" | "block_jacobi" | "neumann" | "ssor"`` or a
  Preconditioner instance; built ONCE at bind time, applied inside the
  overlap window (the single reduction per iteration keeps no
  dependency edge to the in-flight M^{-1}-applied matvec, on every
  binding — asserted at the jaxpr level in the test suite).

The historical free functions (``pbicgsafe_solve``, ``solve_batched``,
``distributed_stencil_solve*``) keep working verbatim but are deprecated
shims now: they re-trace the whole solver on every call, which is
exactly the cost the session amortizes (benchmarks/bench_api.py measures
~10x on 10 repeat solves — larger the more you repeat).
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

import repro  # noqa: E402
from repro.core import SolverConfig  # noqa: E402
from repro.core import matrices as M  # noqa: E402


def solver_demo():
    print("== p-BiCGSafe vs baselines on a convection-diffusion system ==")
    op, b, x_true = M.convection_diffusion(24, peclet=1.0)  # 13824 rows
    for method in ("bicgstab", "ssbicgsafe2", "p-bicgsafe"):
        solver = repro.make_solver(method, op,
                                   config=SolverConfig(tol=1e-8))
        res = solver.solve(b)
        err = float(jnp.linalg.norm(res.x - x_true)
                    / jnp.linalg.norm(x_true))
        print(f"  {method:12s} iterations={int(res.iterations):4d} "
              f"relres={float(res.relres):.2e} x_err={err:.2e}")
    # repeat solves against the bound operator replay the compiled
    # program — no retracing (solver.stats counts traces)
    solver.solve(2.0 * b)
    print(f"  repeat solve reused the program: {solver.stats}")


def precond_demo():
    print("\n== preconditioned p-BiCGSafe (precond= at bind time) ==")
    # hard_nonsym: badly row-scaled — plain p-BiCGSafe stagnates, the
    # preconditioned solve converges in a few dozen iterations with the
    # M^{-1}-apply hidden inside the overlap window.
    op, b, x_true = M.hard_nonsym(n=600)
    cfg = SolverConfig(tol=1e-8, maxiter=3000)
    plain = repro.solve(op, b, config=cfg)
    prec = repro.make_solver("p-bicgsafe", op, precond="block_jacobi",
                             substrate="pallas", config=cfg).solve(b)
    err = float(jnp.linalg.norm(prec.x - x_true) / jnp.linalg.norm(x_true))
    print(f"  unpreconditioned: converged={bool(plain.converged)} "
          f"iterations={int(plain.iterations)}")
    print(f"  block-Jacobi (pallas apply): converged={bool(prec.converged)} "
          f"iterations={int(prec.iterations)} x_err={err:.2e}")
    # SSOR on the stencil family: same front door, name spec
    op, b, _ = M.anisotropic3d(10, eps=1e-2)
    plain = repro.solve(op, b, config=cfg)
    prec = repro.solve(op, b, precond="ssor", config=cfg)
    print(f"  anisotropic3d: {int(plain.iterations)} iters -> "
          f"{int(prec.iterations)} with precond='ssor'")


def multirhs_demo():
    print("\n== batched multi-RHS p-BiCGSafe (one (9, m) reduction/iter) ==")
    op, b, _ = M.poisson3d(10)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    cols = [b] + [jax.random.normal(k, b.shape, b.dtype) for k in keys]
    solver = repro.make_solver("p-bicgsafe", op,
                               config=SolverConfig(tol=1e-8))
    res = solver.solve_many(cols)            # per-column vectors accepted
    for j in range(len(cols)):
        print(f"  rhs {j}: iterations={int(res.iterations[j]):4d} "
              f"relres={float(res.relres[j]):.2e} "
              f"converged={bool(res.converged[j])}")
    # same solve on the hand-tiled (n, m) block kernels (compiled on TPU,
    # interpret mode elsewhere) — same trajectory column by column; the
    # stopping iteration may flip by one where relres hovers at tol (the
    # kernel accumulates block-wise, jnp pairwise)
    res_k = repro.make_solver("p-bicgsafe", op, substrate="pallas",
                              config=SolverConfig(tol=1e-8)).solve_many(cols)
    same = [abs(int(res_k.iterations[j]) - int(res.iterations[j])) <= 1
            for j in range(len(cols))]
    print(f"  substrate='pallas' block kernels: converged="
          f"{bool(res_k.converged.all())}, per-column iteration "
          f"counts within +-1 of jnp: {all(same)}")
    # heterogeneous tolerances are per-column runtime arguments — one
    # compiled program serves every mix (what repro.service rides on)
    het = solver.solve_many(cols[:3], tol=jnp.asarray([1e-4, 1e-8, 1e-10]))
    print(f"  per-column tol [1e-4, 1e-8, 1e-10]: iterations="
          f"{[int(i) for i in het.iterations]}")


def lm_demo():
    print("\n== 5 training steps of a reduced qwen3 config ==")
    from repro.configs import smoke_config
    from repro.data import DataConfig
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, train

    cfg = smoke_config("qwen3-8b")
    out = train(cfg,
                DataConfig(batch_size=2, seq_len=32,
                           vocab_size=cfg.vocab_size),
                TrainConfig(steps=5, ckpt_every=100,
                            ckpt_dir="/tmp/repro-quickstart",
                            opt=AdamWConfig(lr=1e-3)))
    for h in out["history"]:
        print(f"  step {h['step']}: loss {h['loss']:.4f}")


if __name__ == "__main__":
    solver_demo()
    precond_demo()
    multirhs_demo()
    lm_demo()
