"""Quickstart: serving solve requests with continuous batching.

  PYTHONPATH=src python examples/serve_solver.py

The library-call way to solve ``A x = b`` is a bound session
(``repro.make_solver(...).solve(b)``) per right-hand side.  A service
multiplexes instead: :class:`repro.service.SolveEngine` keeps one resident
``(n, max_batch)`` block per registered operator, steps ALL resident
requests with ONE compiled program (one (9, m) fused reduction per
iteration — the paper's single synchronization phase, amortized over
every resident request), retires converged columns at chunk boundaries,
and splices queued requests into the freed slots mid-flight.

This demo registers TWO operators (a Poisson stencil, and a
block-Jacobi-preconditioned convection-diffusion stencil), enqueues a
mixed stream of requests with heterogeneous tolerances and budgets
against both, drains the engine, and prints per-request telemetry.
Re-registering an operator with the same content is a fingerprint cache
hit: the engine's registry consumes the :mod:`repro.api` session cache,
so the built preconditioner and the compiled step programs are reused —
even across engines, or with a direct ``repro.make_solver`` of the same
operator.

The engine serves with ``trace_cap`` set, so every retirement carries a
per-iteration :class:`repro.observe.ConvergenceTrace` (harvested with
the one host read the engine already does), and the whole run lands in
the observe layer: spans are dumped as Chrome trace-event JSON, metrics
as a Prometheus snapshot, one request's trace as convergence JSON —
render them with ``python -m repro.observe report``.
"""
import numpy as np

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import matrices as M          # noqa: E402
from repro.observe import RECORDER, prometheus  # noqa: E402
from repro.service import ServiceConfig, SolveEngine  # noqa: E402

OUT = "experiments/runtime/observe"


def main():
    op_a, b_a, _ = M.poisson3d(8)                        # n = 512, SPD
    op_b, b_b, _ = M.convection_diffusion(8, peclet=1.0)  # non-symmetric

    eng = SolveEngine(ServiceConfig(max_batch=8, chunk=12,
                                    tol=1e-8, maxiter=2000,
                                    trace_cap=128))
    eng.register(op_a, name="poisson")
    eng.register(op_b, precond="block_jacobi", name="convdiff")

    # same content, fresh objects -> cache hit, nothing rebuilt
    assert eng.register(M.poisson3d(8)[0], name="poisson") == "poisson"
    assert len(eng.registry.entries()) == 2

    rng = np.random.default_rng(0)
    n_req = 20
    print(f"submitting {n_req} requests against 2 operators "
          f"(slots: {eng.scfg.max_batch}/operator, heterogeneous tol)")
    for i in range(n_req):
        name = "poisson" if i % 2 == 0 else "convdiff"
        b = jnp.asarray(rng.standard_normal(512))
        tol = float(rng.choice([1e-6, 1e-8, 1e-10]))
        eng.submit(name, b, tol=tol, maxiter=500)

    results = eng.run()

    print(f"\n{'rid':>3} {'operator':<9} {'conv':<5} {'iters':>5} "
          f"{'relres':>9} {'wait ms':>8} {'wall ms':>8} {'chunks':>6}")
    for r in sorted(results, key=lambda r: r.rid):
        t = r.telemetry
        print(f"{r.rid:>3} {r.operator:<9} {str(r.converged):<5} "
              f"{r.iterations:>5} {r.relres:>9.1e} "
              f"{t.queue_wait_s * 1e3:>8.1f} {t.wall_s * 1e3:>8.1f} "
              f"{t.chunks_resident:>6}")

    conv = sum(r.converged for r in results)
    chunks = np.mean([r.telemetry.chunks_resident for r in results])
    print(f"\n{conv}/{n_req} converged; mean chunks resident "
          f"{chunks:.1f}; every iteration of a resident block is ONE "
          "(9, m) reduction for all its requests")

    # -- dump the observe artifacts for the report CLI -------------------
    import os
    os.makedirs(OUT, exist_ok=True)
    RECORDER.save_chrome_trace(f"{OUT}/spans.trace.json")
    with open(f"{OUT}/metrics.prom", "w") as fh:
        fh.write(prometheus())
    slowest = max(results, key=lambda r: r.iterations)
    slowest.trace.save(f"{OUT}/convergence.json")
    print(f"\nslowest request (rid {slowest.rid}): "
          f"{slowest.trace.summary()}")
    print(f"observe artifacts in {OUT}/ — render the timeline with:\n"
          f"  PYTHONPATH=src python -m repro.observe report --dir {OUT}")


if __name__ == "__main__":
    main()
